"""Serving engine + workload generators."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layout import PlaneConfig
from repro.data import kvworkload
from repro.serving.engine import Engine, EngineConfig


def mk_engine(plane, n_objs=256, frames=12, dispatch="pipelined", **kw):
    ekw = {k: kw.pop(k) for k in ("evac_budget", "evac_every", "epoch_every",
                                  "epoch_watermark_bytes", "shards",
                                  "shard_budget")
           if k in kw}
    pcfg = PlaneConfig(num_objs=n_objs, obj_dim=8, page_objs=8,
                      num_frames=frames, num_vpages=3 * (n_objs // 8), **kw)
    data = jnp.arange(n_objs * 8, dtype=jnp.float32).reshape(n_objs, 8)
    return Engine(EngineConfig(plane=plane, batch=16, dispatch=dispatch,
                               **ekw), pcfg, data), data


@pytest.mark.parametrize("plane", ["hybrid", "paging", "object"])
def test_engine_serves_correct_values(plane):
    eng, data = mk_engine(plane)
    rng = np.random.RandomState(0)
    for _ in range(6):
        ids = rng.randint(0, 256, size=16).astype(np.int32)
        rows = eng.serve_batch(ids)
        np.testing.assert_allclose(np.asarray(rows), np.asarray(data)[ids])
    stats = eng.latency.summary()
    assert stats["n"] == 96
    assert stats["p90_us"] > 0


def test_engine_run_reports():
    eng, _ = mk_engine("hybrid")
    wl = kvworkload.zipf_churn(256, 16, steps=30, seed=1)
    rep = eng.run(wl)
    assert rep["stats"]["hits"] + rep["stats"]["misses"] == 480
    assert 0.0 <= rep["paging_fraction"] <= 1.0


@pytest.mark.parametrize("name", list(kvworkload.WORKLOADS))
def test_workloads_in_range(name):
    gen = kvworkload.WORKLOADS[name](128, 16, steps=10, seed=3)
    for ids in gen:
        assert ids.dtype == np.int32
        assert ids.min() >= 0 and ids.max() < 128
        assert len(ids) == 16


def test_sequential_workload_favors_paging_hybrid():
    """On a pure scan the hybrid plane should behave like paging (no object
    fetches after warmup)."""
    eng, _ = mk_engine("hybrid")
    rep = eng.run(kvworkload.scan(256, 16, steps=40))
    assert rep["stats"]["obj_ins"] == 0
    assert rep["stats"]["page_ins"] > 0
    assert rep["paging_fraction"] > 0.9


def test_skewed_workload_engages_runtime_path():
    eng, _ = mk_engine("hybrid")
    rep = eng.run(kvworkload.uniform(256, 16, steps=60))
    assert rep["stats"]["obj_ins"] > 0          # hybrid flipped to objects


@pytest.mark.parametrize("plane", ["hybrid", "paging", "object"])
def test_pipelined_matches_sync(plane):
    """The double-buffered plan/execute pipeline must produce exactly the
    rows and final plane state of synchronous dispatch — the overlap is
    pure scheduling, never a semantic change."""
    eng_p, data = mk_engine(plane, dispatch="pipelined")
    eng_s, _ = mk_engine(plane, dispatch="sync")
    batches = list(kvworkload.zipf_churn(256, 16, steps=25, seed=9))
    futs = [eng_p.submit(ids) for ids in batches]
    eng_p.drain()
    rows_p = [np.asarray(f) for f in futs]
    rows_s = [np.asarray(eng_s.serve_batch(ids)) for ids in batches]
    for i, (rp, rs) in enumerate(zip(rows_p, rows_s)):
        np.testing.assert_array_equal(rp, rs, err_msg=f"batch {i}")
        np.testing.assert_array_equal(rp, np.asarray(data)[batches[i]])
    for field in eng_p.state._fields:
        for x, y in zip(jax.tree_util.tree_leaves(getattr(eng_p.state, field)),
                        jax.tree_util.tree_leaves(getattr(eng_s.state, field))):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"PlaneState.{field} diverged ({plane})")
    # pipelined engine recorded every request's latency exactly once
    assert eng_p.latency.summary()["n"] == sum(len(b) for b in batches)


def test_background_evacuation_slices_serve_correct_values():
    """evac_budget > 0: evacuation runs as small plan/execute slices inside
    the dispatch gaps instead of one blocking foreground compaction — same
    served values, evacuation actually happening, state invariants held."""
    from repro.core import check_invariants
    # threshold -1: every local page qualifies, so the 2-page slices are
    # guaranteed to compact continuously under the serving loop
    eng, data = mk_engine("hybrid", evac_budget=2, evac_every=4,
                          evac_garbage_threshold=-1.0)
    rng = np.random.RandomState(7)
    for _ in range(30):
        ids = rng.randint(0, 256, size=16).astype(np.int32)
        rows = eng.serve_batch(ids)
        np.testing.assert_allclose(np.asarray(rows), np.asarray(data)[ids])
    assert int(eng.state.stats.evac_pages) > 0       # slices did real work
    assert all(check_invariants(eng.pcfg, eng.state).values())


def test_engine_epoch_governor_runs():
    """epoch_every > 0 schedules advance_epoch between batches; served
    values stay ground truth and the epoch counter advances."""
    eng, data = mk_engine("hybrid", epoch_every=4)
    rng = np.random.RandomState(8)
    for _ in range(20):
        ids = rng.randint(0, 256, size=16).astype(np.int32)
        rows = eng.serve_batch(ids)
        np.testing.assert_allclose(np.asarray(rows), np.asarray(data)[ids])
    assert int(eng.state.stats.epochs) == 5


def test_epoch_watermark_advances_on_churn_burst():
    """Load-aware epoch scheduling: a churn burst (all-miss traffic) must
    close epochs faster than the wall-clock tick schedule.  Both engines
    share the tick fallback; only one has the byte watermark armed."""
    mk = lambda wm: mk_engine("hybrid", epoch_every=50,
                              epoch_watermark_bytes=wm, dispatch="sync")[0]
    eng_tick, eng_wm = mk(0), mk(2048)
    rng = np.random.RandomState(12)
    burst = [rng.permutation(256)[:16].astype(np.int32) for _ in range(40)]
    rep_tick = eng_tick.run(iter(burst))
    rep_wm = eng_wm.run(iter(burst))
    # 40 ticks never reach the 50-tick fallback; the watermark keyed off
    # the actual paging+object byte traffic and kept the governor hot
    assert rep_tick["stats"]["epochs"] == 0
    assert rep_wm["stats"]["epochs"] >= 5
    # served values stay ground truth under watermark epochs
    eng2, data = mk_engine("hybrid", epoch_every=50,
                           epoch_watermark_bytes=2048, dispatch="sync")
    ids = rng.randint(0, 256, size=16).astype(np.int32)
    np.testing.assert_allclose(np.asarray(eng2.serve_batch(ids)),
                               np.asarray(data)[ids])


def test_latency_charged_from_scheduled_arrival():
    """Queueing under saturation must show up in the latency numbers: with
    a paced workload whose interarrival is far below the service time, the
    recorded mean must exceed the interarrival (the old accounting reset
    the clock after the pacing sleep and hid the queue entirely)."""
    eng, _ = mk_engine("hybrid", dispatch="sync")
    batches = list(kvworkload.zipf_churn(256, 16, steps=20, seed=4))
    # measure service time, then offer 5x that rate
    t0 = time.time()
    for b in batches[:5]:
        eng.serve_batch(b)
    service = (time.time() - t0) / 5
    eng.latency = type(eng.latency)()
    rep = eng.run(batches[5:], offered_interarrival_s=service / 5)
    assert rep["latency"]["mean_us"] > (service / 5) * 1e6
