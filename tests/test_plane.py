"""Unit tests for the hybrid data plane (core contribution)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from functools import partial

from repro.core import (FREE, LOCAL, REMOTE, PlaneConfig, access, create,
                        evacuate, evict_all, paging_fraction, peek, update,
                        writeback_all, check_invariants, jitted_access,
                        jitted_evacuate, jitted_update)
from repro.core import paths, sync


def mk(num_objs=96, obj_dim=4, page_objs=8, num_frames=6, num_vpages=40, **kw):
    cfg = PlaneConfig(num_objs=num_objs, obj_dim=obj_dim, page_objs=page_objs,
                      num_frames=num_frames, num_vpages=num_vpages, **kw)
    data = jnp.arange(num_objs * obj_dim, dtype=jnp.float32
                      ).reshape(num_objs, obj_dim)
    return cfg, data, create(cfg, data)


def test_create_layout():
    cfg, data, s = mk()
    assert int((s.backing == REMOTE).sum()) == cfg.data_pages
    assert int((s.backing == FREE).sum()) == cfg.num_vpages - cfg.data_pages
    np.testing.assert_allclose(np.asarray(peek(cfg, s, jnp.arange(96))),
                               np.asarray(data))
    assert all(check_invariants(cfg, s).values())


def test_sequential_access_takes_paging():
    cfg, data, s = mk()
    acc = jitted_access(cfg)
    s, rows = acc(s, jnp.arange(16, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(rows), np.asarray(data[:16]))
    assert int(s.stats.page_ins) == 2           # 2 pages of 8 objects
    assert int(s.stats.obj_ins) == 0
    assert int(s.stats.hits) == 14


def test_random_access_flips_to_runtime():
    cfg, data, s = mk()
    acc = jitted_access(cfg)
    rng = np.random.RandomState(0)
    for _ in range(10):
        ids = jnp.asarray(rng.choice(96, 12, replace=False), jnp.int32)
        s, rows = acc(s, ids)
        np.testing.assert_allclose(np.asarray(rows), np.asarray(data[ids]))
    assert int(s.stats.psf_to_runtime) > 0      # PSF flipped under low CAR
    assert int(s.stats.obj_ins) > 0             # runtime path engaged
    assert all(check_invariants(cfg, s).values())


def test_psf_only_changes_at_pageout():
    """Invariant #1: PSF of a page never changes while it is resident."""
    cfg, data, s = mk()
    acc = jitted_access(cfg)
    rng = np.random.RandomState(1)
    for _ in range(6):
        before_psf = np.asarray(s.psf)
        before_backing = np.asarray(s.backing)
        ids = jnp.asarray(rng.choice(96, 10, replace=False), jnp.int32)
        s, _ = acc(s, ids)
        after_psf = np.asarray(s.psf)
        after_backing = np.asarray(s.backing)
        # pages that stayed LOCAL throughout must keep their PSF
        stayed = (before_backing == LOCAL) & (after_backing == LOCAL)
        assert np.all(after_psf[stayed] == before_psf[stayed])


def test_update_dirty_writeback():
    cfg, data, s = mk()
    ids = jnp.asarray([5, 40, 80], jnp.int32)
    rows = -jnp.ones((3, 4), jnp.float32)
    s = jitted_update(cfg)(s, ids, rows)
    s = jax.jit(partial(writeback_all, cfg))(s)
    s = jax.jit(partial(evict_all, cfg))(s)
    np.testing.assert_allclose(np.asarray(peek(cfg, s, ids)), np.asarray(rows))
    assert all(check_invariants(cfg, s).values())


def test_evacuation_compacts_and_segregates():
    cfg, data, s = mk(num_frames=8)
    acc = jitted_access(cfg)
    rng = np.random.RandomState(2)
    # object-path churn creates garbage on source pages
    for _ in range(20):
        ids = jnp.asarray(rng.choice(96, 12), jnp.int32)
        s, _ = acc(s, ids)
    pre_moved = int(s.stats.evac_moved)
    s2 = jitted_evacuate(cfg, garbage_threshold=0.05)(s)
    assert all(check_invariants(cfg, s2).values())
    # data is preserved through compaction
    np.testing.assert_allclose(
        np.asarray(peek(cfg, s2, jnp.arange(96))), np.asarray(data))
    # access bits cleared at end of evacuation (paper §4.3)
    assert not bool(s2.access.any())


def test_pinned_pages_never_evicted():
    """Invariant #2: a pinned page survives eviction pressure."""
    cfg, data, s = mk(num_frames=4)
    acc = jitted_access(cfg)
    s, _ = acc(s, jnp.arange(8, dtype=jnp.int32))      # page 0 resident
    v0 = int(s.obj_loc[0]) // cfg.page_objs
    s = sync.pin_objects(cfg, s, jnp.asarray([0], jnp.int32))
    # hammer other pages to force evictions
    for start in range(8, 96, 8):
        s, _ = acc(s, jnp.arange(start, start + 8, dtype=jnp.int32))
    assert int(s.backing[v0]) == LOCAL
    s = sync.unpin_objects(cfg, s, jnp.asarray([0], jnp.int32))
    assert int(s.pin[v0]) == 0


def test_livelock_guard_forces_paging():
    cfg, data, s = mk(num_frames=4)
    acc = jitted_access(cfg)
    s, _ = acc(s, jnp.arange(24, dtype=jnp.int32))
    ids = jnp.arange(8, dtype=jnp.int32)
    s = sync.pin_objects(cfg, s, ids)
    s2 = sync.force_paging_under_pressure(cfg, s, threshold=0.0)
    v = np.asarray(s2.obj_loc[ids]) // cfg.page_objs
    assert np.all(np.asarray(s2.psf)[v])
    s2 = sync.unpin_objects(cfg, s2, ids)
    assert all(check_invariants(cfg, s2).values())


def test_car_threshold_behavior():
    """High CAR -> paging; low CAR -> runtime (paper Fig 10 mechanism)."""
    cfg, data, s = mk(car_threshold=0.8)
    acc = jitted_access(cfg)
    # touch every object on page 1 (full CAR), single object on page 5
    s, _ = acc(s, jnp.arange(8, 16, dtype=jnp.int32))
    s, _ = acc(s, jnp.asarray([40], jnp.int32))
    s = jax.jit(partial(evict_all, cfg))(s)
    assert bool(s.psf[1])          # CAR = 1.0 -> paging
    assert not bool(s.psf[5])      # CAR = 1/8 -> runtime


def test_offload_remote_apply():
    from repro.core import offload
    cfg, data, s = mk()
    vpages = jnp.asarray([0, 3, 7], jnp.int32)
    s, sums = offload.remote_apply(cfg, s, vpages,
                                   lambda page: page.sum())
    expect = [float(data[v * 8:(v + 1) * 8].sum()) for v in [0, 3, 7]]
    np.testing.assert_allclose(np.asarray(sums), expect, rtol=1e-6)
    assert np.all(np.asarray(s.pin[vpages]) == 1)   # offload-busy pins
    s = offload.remote_release(cfg, s, vpages)
    assert all(check_invariants(cfg, s).values())


def test_offload_pin_balance_mixed_tiers():
    """Regression for the single-source remote_apply: a mixed local/remote
    request (with duplicate vpages) returns correct per-page results, and
    release restores the exact pin vector — every +1 taken by apply
    (including duplicates) is matched by release."""
    from repro.core import offload
    cfg, data, s = mk()
    acc = jitted_access(cfg)
    s, _ = acc(s, jnp.arange(8, dtype=jnp.int32))     # page 0 now LOCAL
    assert int(s.backing[0]) == LOCAL and int(s.backing[3]) == REMOTE
    pins0 = np.asarray(s.pin).copy()
    vpages = jnp.asarray([0, 3, 3, 7], jnp.int32)     # duplicates included
    s2, sums = offload.remote_apply(cfg, s, vpages, lambda page: page.sum())
    expect = [float(data[v * 8:(v + 1) * 8].sum()) for v in [0, 3, 3, 7]]
    np.testing.assert_allclose(np.asarray(sums), expect, rtol=1e-6)
    assert int(s2.pin[0]) == pins0[0] + 1
    assert int(s2.pin[3]) == pins0[3] + 2             # one pin per occurrence
    s3 = offload.remote_release(cfg, s2, vpages)
    np.testing.assert_array_equal(np.asarray(s3.pin), pins0)
    assert all(check_invariants(cfg, s3).values())
