"""Production plane integrations: tiered KV cache + expert store."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expertplane as ep
from repro.core import kvplane

RNG = np.random.RandomState(3)


def _naive_attn(q, K, V, G):
    H, Dh = q.shape
    out = np.zeros((H, Dh))
    for h in range(H):
        kvh = h // G
        sc = (K[:, kvh] @ q[h]) / np.sqrt(Dh)
        w = np.exp(sc - sc.max()); w /= w.sum()
        out[h] = w @ V[:, kvh]
    return out


def test_dense_plane_matches_full_attention():
    cfg = kvplane.KVPlaneConfig(kv_heads=2, head_dim=16, page_tokens=4,
                                num_pages=8, num_frames=16, batch=2,
                                dtype=jnp.float32)
    s = kvplane.init(cfg)
    lengths = jnp.zeros((2,), jnp.int32)
    Ks, Vs = [], []
    for t in range(13):
        kn = jnp.asarray(RNG.randn(2, 2, 16), jnp.float32)
        vn = jnp.asarray(RNG.randn(2, 2, 16), jnp.float32)
        Ks.append(np.asarray(kn)); Vs.append(np.asarray(vn))
        s = kvplane.append_dense(cfg, s, kn, vn, lengths)
        lengths = lengths + 1
    q = jnp.asarray(RNG.randn(2, 4, 16), jnp.float32)
    out, s = kvplane.attend_dense(cfg, s, q, lengths)
    K = np.stack(Ks, 1); V = np.stack(Vs, 1)
    for b in range(2):
        np.testing.assert_allclose(np.asarray(out)[b],
                                   _naive_attn(np.asarray(q)[b], K[b], V[b], 2),
                                   rtol=1e-4, atol=1e-4)
    # dense touch -> CAR = 1 on covered pages (stays paging)
    assert bool(s.psf.all())


def test_sharded_sparse_exact_when_topk_covers():
    D, KVH, G, Dh, P, NPs = 2, 2, 2, 16, 4, 8
    cfg = kvplane.KVPlaneConfig(kv_heads=KVH, head_dim=Dh, page_tokens=P,
                                num_pages=NPs, num_frames=NPs, batch=1,
                                sparse_topk=NPs, fetch_budget=NPs,
                                dtype=jnp.float32)
    states = jax.vmap(lambda _: kvplane.init(cfg))(jnp.arange(D))
    T = 45
    Ks = RNG.randn(T, KVH, Dh).astype(np.float32)
    Vs = RNG.randn(T, KVH, Dh).astype(np.float32)
    lengths = jnp.asarray([0], jnp.int32)
    app = jax.jit(partial(kvplane.append_sharded, cfg))
    for t in range(T):
        states = app(states, jnp.asarray(Ks[t:t+1]), jnp.asarray(Vs[t:t+1]),
                     lengths)
        lengths = lengths + 1
    q = jnp.asarray(RNG.randn(1, KVH * G, Dh), jnp.float32)
    dec = jax.jit(partial(kvplane.sharded_sparse_decode, cfg))
    out, states = dec(states, q, lengths)   # warm-up fetch
    out, states = dec(states, q, lengths)
    np.testing.assert_allclose(np.asarray(out)[0],
                               _naive_attn(np.asarray(q)[0], Ks, Vs, G),
                               rtol=1e-4, atol=1e-4)


def test_sparse_psf_dynamics_and_packing():
    """Alternating skewed queries churn the frame pool: evicted pages whose
    attention concentrated on one row flip PSF to runtime, record a hot
    hint, and subsequent fetches arrive packed (few rows)."""
    D, KVH, Dh, P, NPs = 1, 1, 16, 8, 8
    cfg = kvplane.KVPlaneConfig(kv_heads=KVH, head_dim=Dh, page_tokens=P,
                                num_pages=NPs, num_frames=2, batch=1,
                                sparse_topk=2, fetch_budget=2,
                                car_threshold=0.8, dtype=jnp.float32)
    states = jax.vmap(lambda _: kvplane.init(cfg))(jnp.arange(D))
    T = NPs * P
    Ks = RNG.randn(T, KVH, Dh).astype(np.float32) * 0.05
    Ks[1 * P + 3] = 3.0        # page 1 magnet (for q = +1)
    Ks[4 * P + 5] = -3.0       # page 4 magnet (for q = -1)
    Vs = RNG.randn(T, KVH, Dh).astype(np.float32)
    lengths = jnp.asarray([0], jnp.int32)
    app = jax.jit(partial(kvplane.append_sharded, cfg))
    for t in range(T):
        states = app(states, jnp.asarray(Ks[t:t+1]), jnp.asarray(Vs[t:t+1]),
                     lengths)
        lengths = lengths + 1
    dec = jax.jit(partial(kvplane.sharded_sparse_decode, cfg))
    qp = jnp.ones((1, KVH, Dh), jnp.float32)
    for i in range(16):
        q = qp if i % 2 == 0 else -qp   # alternate magnets -> churn
        out, states = dec(states, q, lengths)
        assert bool(jnp.isfinite(out).all())
    # magnet pages flipped to runtime at eviction and recorded hot hints
    psf = np.asarray(states.psf)[0, 0]
    hints = np.asarray(states.hot_hint)[0, 0]
    assert not psf[1] or not psf[4], psf
    assert hints.any()
    # the hint marks few rows of the page (packed fetch would be small)
    assert hints.sum() <= 2 * 3


def test_expert_plane_lru_and_correctness():
    E, d, f, S, K = 8, 16, 32, 4, 2
    wi = jnp.asarray(RNG.randn(E, d, f) * 0.1, jnp.float32)
    wg = jnp.asarray(RNG.randn(E, d, f) * 0.1, jnp.float32)
    wo = jnp.asarray(RNG.randn(E, f, d) * 0.1, jnp.float32)
    router = jnp.asarray(RNG.randn(d, E), jnp.float32)
    cfg = ep.ExpertPlaneConfig(n_experts=E, d_model=d, d_ff=f, hot_slots=S,
                               topk=K, fetch_budget=4, dtype=jnp.float32)
    s = ep.init(cfg)
    step = jax.jit(partial(ep.moe_decode, cfg))
    # 2 tokens x top-2 <= 4 unique experts <= hot slots: a true steady state
    x = jnp.asarray(RNG.randn(2, d), jnp.float32)
    y1, s = step(s, router, x, wi, wg, wo)
    y2, s = step(s, router, x, wi, wg, wo)
    assert int((s.slot_of >= 0).sum()) <= S
    assert bool(jnp.isfinite(y2).all())
    # steady state: same tokens -> resident experts -> deterministic output
    y3, s = step(s, router, x, wi, wg, wo)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), rtol=1e-5)
    # access profiling counts needed experts
    assert int(s.access.sum()) > 0
