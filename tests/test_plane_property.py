"""Property-based tests (hypothesis): the plane's invariants hold under
arbitrary access/update/evacuate interleavings, and reads always return
ground truth."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (PlaneConfig, access, baselines, check_invariants,
                        create, evacuate, evict_all, peek, update,
                        writeback_all)

CFG = PlaneConfig(num_objs=48, obj_dim=4, page_objs=4, num_frames=5,
                  num_vpages=36)
DATA = jnp.arange(48 * 4, dtype=jnp.float32).reshape(48, 4)

_ACC = jax.jit(partial(access, CFG))
_UPD = jax.jit(partial(update, CFG))
_EVA = jax.jit(partial(evacuate, CFG, garbage_threshold=0.2))
_EVI = jax.jit(partial(evict_all, CFG))
_OBJ = jax.jit(partial(baselines.object_access, CFG))
_PAG = jax.jit(partial(baselines.paging_access, CFG))

op_st = st.tuples(
    st.sampled_from(["access", "update", "evacuate", "evict_all"]),
    st.lists(st.integers(0, 47), min_size=1, max_size=6))


@settings(max_examples=25, deadline=None)
@given(st.lists(op_st, min_size=1, max_size=12), st.integers(0, 2 ** 31 - 1))
def test_hybrid_plane_interleavings(ops, seed):
    s = create(CFG, DATA)
    shadow = np.asarray(DATA).copy()
    rng = np.random.RandomState(seed % (2**31 - 1))
    for kind, ids in ops:
        ids = jnp.asarray(ids, jnp.int32)
        if kind == "access":
            s, rows = _ACC(s, ids)
            np.testing.assert_allclose(np.asarray(rows), shadow[np.asarray(ids)],
                                       err_msg=f"read mismatch {ids}")
        elif kind == "update":
            rows = rng.randn(len(ids), 4).astype(np.float32)
            # duplicate ids in one batch: last-writer-wins per the loop order
            s = _UPD(s, ids, jnp.asarray(rows))
            for i, o in enumerate(np.asarray(ids)):
                shadow[o] = rows[i]
        elif kind == "evacuate":
            s = _EVA(s)
        else:
            s = _EVI(s)
    inv = check_invariants(CFG, s)
    assert all(inv.values()), inv
    np.testing.assert_allclose(
        np.asarray(peek(CFG, s, jnp.arange(48))), shadow)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.lists(st.integers(0, 47), min_size=1, max_size=8),
                min_size=1, max_size=8))
def test_object_plane_reads_correct(batches):
    s = create(CFG, DATA)
    for ids in batches:
        s, rows = _OBJ(s, jnp.asarray(ids, jnp.int32))
        np.testing.assert_allclose(np.asarray(rows),
                                   np.asarray(DATA)[np.asarray(ids)])
    inv = check_invariants(CFG, s)
    assert all(inv.values()), inv


@settings(max_examples=15, deadline=None)
@given(st.lists(st.lists(st.integers(0, 47), min_size=1, max_size=8),
                min_size=1, max_size=8))
def test_paging_plane_reads_correct(batches):
    s = create(CFG, DATA)
    for ids in batches:
        s, rows = _PAG(s, jnp.asarray(ids, jnp.int32))
        np.testing.assert_allclose(np.asarray(rows),
                                   np.asarray(DATA)[np.asarray(ids)])


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 47), min_size=1, max_size=10))
def test_car_bounded(ids):
    from repro.core.paths import car_of
    s = create(CFG, DATA)
    s, _ = _ACC(s, jnp.asarray(ids, jnp.int32))
    for v in range(CFG.num_vpages):
        car = float(car_of(CFG, s, jnp.asarray(v)))
        assert 0.0 <= car <= 1.0
