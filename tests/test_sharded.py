"""Sharded far tier (repro.core.shardplane).

Two layers of bit-equivalence, mirroring the plan/execute discipline:

  * always-on (1 device): the vmapped sharded oracle serves ground-truth
    rows on random / skewed / sequential workloads, degenerates to the
    plain plane BITWISE (stats included) at ``shards=1``, spills + drains
    overflow under a small exchange budget, moves every shard's governor
    threshold in lockstep, and runs the overlap-pipelined exchange
    bit-identically to the serial schedule (spill path and shard-targeted
    outage windows included).
  * 8 simulated devices (CI job tier1-sharded, XLA_FLAGS=
    --xla_force_host_platform_device_count=8): the shard_map data path is
    bit-identical to the oracle — rows and full final state — for
    shards in {2, 4, 8}, including the spill path, update, the epoch
    all_gather, evacuation, the kvplane sharded decode, the serving
    engine end to end, and the overlap suite (overlap == serial on
    devices; the fused payloads cut the traced collective count from 3
    to 2 per round).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batch as batch_lib
from repro.core import faults
from repro.core import kvplane, plane as plane_lib, shardplane
from repro.core import state as state_lib
from repro.core.layout import PlaneConfig
from repro.launch import mesh as mesh_lib

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

O, D, R = 256, 8, 16            # global objects / row dim / per-shard batch
GCFG = PlaneConfig(num_objs=O, obj_dim=D, page_objs=4, num_frames=48,
                   num_vpages=192)


def initial_data():
    return jnp.arange(O * D, dtype=jnp.float32).reshape(O, D)


def workload(name, shards, steps, seed=0):
    """[steps, shards, R] global object ids (may include duplicates)."""
    rng = np.random.default_rng(seed)
    n = steps * shards * R
    if name == "random":
        ids = rng.integers(0, O, size=n)
    elif name == "skewed":
        ids = rng.zipf(1.5, size=n) % O
    else:                                           # sequential scan
        ids = np.arange(n) % O
    return ids.reshape(steps, shards, R).astype(np.int32)


def assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg} leaf {i}")


# --------------------------------------------------------------------------
# single-device oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [2, 4, 8])
@pytest.mark.parametrize("wl", ["random", "skewed", "sequential"])
def test_sharded_rows_ground_truth(shards, wl):
    scfg = shardplane.make_config(GCFG, shards, R)
    data = initial_data()
    states = shardplane.create(scfg, data)
    acc = shardplane.jitted_access(scfg)
    for ids in workload(wl, shards, steps=8, seed=shards):
        states, rows = acc(states, jnp.asarray(ids))
        np.testing.assert_array_equal(
            np.asarray(rows).reshape(shards * R, D),
            np.asarray(data)[ids.reshape(-1)])
    assert all(shardplane.check_invariants(scfg, states).values())
    assert int(shardplane.stats_total(states).ingress_spills) == 0


def test_shards1_matches_plain_plane_bitwise():
    """shards=1, default budget: the exchange is a no-op wrapper and the
    sharded plane IS the plain plane — rows, state and every stat."""
    scfg = shardplane.make_config(GCFG, 1, R)
    data = initial_data()
    states = shardplane.create(scfg, data)
    plain = state_lib.create(GCFG, data)
    acc = shardplane.jitted_access(scfg)
    rng = np.random.default_rng(3)
    for t in range(12):
        ids = rng.integers(0, O, size=R).astype(np.int32)
        ids[1] = ids[0]                             # force duplicates
        states, rows_s = acc(states, jnp.asarray(ids)[None])
        plain, rows_p = batch_lib.access(GCFG, plain, jnp.asarray(ids))
        np.testing.assert_array_equal(np.asarray(rows_s)[0],
                                      np.asarray(rows_p), err_msg=f"t={t}")
    assert_trees_equal(state_lib.shard_slice(states, 0), plain,
                       "shards=1 state")


def test_spill_path_serves_and_counts():
    """budget < shard_batch with every id hitting one owner: overflow
    spills to later rounds (counted), yet every request is served within
    the one access call."""
    shards = 4
    scfg = shardplane.make_config(GCFG, shards, R, per_shard_budget=3)
    assert scfg.rounds == 6
    data = initial_data()
    states = shardplane.create(scfg, data)
    acc = shardplane.jitted_access(scfg)
    rng = np.random.default_rng(11)
    for _ in range(4):
        # all requests target owner shard 0's objects -> worst-case skew
        ids = rng.integers(0, O // shards, size=(shards, R)).astype(np.int32)
        states, rows = acc(states, jnp.asarray(ids))
        np.testing.assert_array_equal(
            np.asarray(rows).reshape(-1, D), np.asarray(data)[ids.reshape(-1)])
    assert int(shardplane.stats_total(states).ingress_spills) > 0
    assert all(shardplane.check_invariants(scfg, states).values())


def test_sharded_padding_rows_are_noops():
    scfg = shardplane.make_config(GCFG, 2, R)
    states = shardplane.create(scfg, initial_data())
    ids = np.full((2, R), -1, np.int32)
    ids[0, 0], ids[1, 3] = 7, 200
    states2, rows = shardplane.jitted_access(scfg)(states, jnp.asarray(ids))
    rows = np.asarray(rows)
    assert np.all(rows[0, 1:] == 0) and np.all(rows[1, :3] == 0)
    np.testing.assert_array_equal(rows[0, 0],
                                  np.asarray(initial_data())[7])
    assert int(shardplane.stats_total(states2).hits
               + shardplane.stats_total(states2).misses) == 2


def test_sharded_update_shards1_matches_plain():
    scfg = shardplane.make_config(GCFG, 1, R)
    data = initial_data()
    states = shardplane.create(scfg, data)
    plain = state_lib.create(GCFG, data)
    upd = shardplane.jitted_update(scfg)
    rng = np.random.default_rng(5)
    for _ in range(6):
        ids = rng.integers(0, O, size=R).astype(np.int32)
        ids[2] = ids[0]                             # duplicate write
        rows = rng.normal(size=(R, D)).astype(np.float32)
        states = upd(states, jnp.asarray(ids)[None], jnp.asarray(rows)[None])
        plain = batch_lib.update(GCFG, plain, jnp.asarray(ids),
                                 jnp.asarray(rows))
    assert_trees_equal(state_lib.shard_slice(states, 0), plain,
                       "shards=1 update state")


def test_sharded_update_then_read_back():
    shards = 4
    scfg = shardplane.make_config(GCFG, shards, R)
    data = initial_data()
    states = shardplane.create(scfg, data)
    rng = np.random.default_rng(6)
    ids = rng.permutation(O)[:shards * R].reshape(shards, R).astype(np.int32)
    rows = rng.normal(size=(shards, R, D)).astype(np.float32)
    states = shardplane.jitted_update(scfg)(states, jnp.asarray(ids),
                                            jnp.asarray(rows))
    states, got = shardplane.jitted_access(scfg)(states, jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(got), rows)
    assert all(shardplane.check_invariants(scfg, states).values())


def test_epoch_thresholds_move_in_lockstep():
    """The governor sees the GLOBAL traffic aggregate, so every shard's
    adaptive threshold takes the same trajectory even under skew that
    loads one shard only."""
    shards = 4
    scfg = shardplane.make_config(GCFG, shards, R)
    states = shardplane.create(scfg, initial_data())
    acc = shardplane.jitted_access(scfg)
    ep = shardplane.jitted_advance_epoch(scfg)
    rng = np.random.default_rng(9)
    for _ in range(6):
        ids = rng.integers(0, O // shards, size=(shards, R)).astype(np.int32)
        states, _ = acc(states, jnp.asarray(ids))
        states = ep(states)
    thr = np.asarray(states.car_thr)
    assert thr.shape[0] == shards
    assert np.all(thr == thr[0])
    assert int(shardplane.stats_total(states).epochs) == 6 * shards


@pytest.mark.parametrize("plane", ["hybrid", "paging"])
def test_sharded_batch_matches_reference(plane):
    """mode='batch' (the vectorized engine) == mode='reference' (the
    scalar oracle) through the sharded exchange too."""
    scfg = shardplane.make_config(GCFG, 2, R, plane=plane)
    data = initial_data()
    sb = shardplane.create(scfg, data)
    sr = shardplane.create(scfg, data)
    ab = shardplane.jitted_access(scfg, mode="batch")
    ar = shardplane.jitted_access(scfg, mode="reference")
    for ids in workload("skewed", 2, steps=5, seed=21):
        sb, rb = ab(sb, jnp.asarray(ids))
        sr, rr = ar(sr, jnp.asarray(ids))
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr))
    assert_trees_equal(sb, sr, f"batch-vs-reference ({plane})")


# --------------------------------------------------------------------------
# mesh construction helpers
# --------------------------------------------------------------------------

def test_make_host_mesh_raises_past_device_count():
    n = jax.device_count()
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        mesh_lib.make_host_mesh(data=n + 1, model=1)


def test_make_far_mesh_raises_past_device_count():
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        mesh_lib.make_far_mesh(jax.device_count() + 1)


def test_make_production_mesh_sizes_to_device_count():
    mesh = mesh_lib.make_production_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == jax.device_count()


# --------------------------------------------------------------------------
# 8 simulated devices: shard_map vs oracle
# --------------------------------------------------------------------------

def _put_far(states, mesh):
    return mesh_lib.put_far(states, mesh)


@needs8
@pytest.mark.parametrize("shards", [2, 4, 8])
@pytest.mark.parametrize("plane,budget", [("hybrid", None), ("hybrid", 3),
                                          ("paging", None)])
def test_shard_map_access_bitwise(shards, plane, budget):
    scfg = shardplane.make_config(GCFG, shards, R, per_shard_budget=budget,
                                  plane=plane)
    data = initial_data()
    s_emu = shardplane.create(scfg, data)
    mesh = mesh_lib.make_far_mesh(shards)
    s_dev = _put_far(s_emu, mesh)
    a_emu = shardplane.jitted_access(scfg)
    a_dev = shardplane.jitted_access(scfg, mesh=mesh)
    for t, ids in enumerate(workload("skewed", shards, steps=6, seed=31)):
        s_emu, r_emu = a_emu(s_emu, jnp.asarray(ids))
        s_dev, r_dev = a_dev(s_dev, jnp.asarray(ids))
        np.testing.assert_array_equal(np.asarray(r_emu), np.asarray(r_dev),
                                      err_msg=f"rows t={t}")
    assert_trees_equal(s_emu, s_dev, f"state ({plane}, budget={budget})")
    if budget is not None:
        assert int(shardplane.stats_total(s_dev).ingress_spills) > 0


@needs8
@pytest.mark.parametrize("shards", [2, 8])
def test_shard_map_update_epoch_evacuate_bitwise(shards):
    scfg = shardplane.make_config(GCFG, shards, R)
    data = initial_data()
    s_emu = shardplane.create(scfg, data)
    mesh = mesh_lib.make_far_mesh(shards)
    s_dev = _put_far(s_emu, mesh)
    acc = (shardplane.jitted_access(scfg),
           shardplane.jitted_access(scfg, mesh=mesh))
    upd = (shardplane.jitted_update(scfg),
           shardplane.jitted_update(scfg, mesh=mesh))
    ep = (shardplane.jitted_advance_epoch(scfg),
          shardplane.jitted_advance_epoch(scfg, mesh=mesh))
    ev = (shardplane.jitted_evacuate(scfg, max_pages=4),
          shardplane.jitted_evacuate(scfg, max_pages=4, mesh=mesh))
    rng = np.random.default_rng(41)
    for t in range(6):
        ids = rng.integers(0, O, size=(shards, R)).astype(np.int32)
        s_emu, _ = acc[0](s_emu, jnp.asarray(ids))
        s_dev, _ = acc[1](s_dev, jnp.asarray(ids))
        rows = rng.normal(size=(shards, R, D)).astype(np.float32)
        s_emu = upd[0](s_emu, jnp.asarray(ids), jnp.asarray(rows))
        s_dev = upd[1](s_dev, jnp.asarray(ids), jnp.asarray(rows))
        if t % 2 == 1:
            s_emu, s_dev = ep[0](s_emu), ep[1](s_dev)
            s_emu, s_dev = ev[0](s_emu), ev[1](s_dev)
    assert_trees_equal(s_emu, s_dev, "update/epoch/evac state")


@needs8
@pytest.mark.parametrize("shards", [2, 4, 8])
def test_kvplane_shard_map_decode_bitwise(shards):
    cfg = kvplane.KVPlaneConfig(kv_heads=1, head_dim=8, page_tokens=4,
                                num_pages=8, num_frames=3, batch=1,
                                sparse_topk=3, fetch_budget=2,
                                car_threshold=0.5, dtype=jnp.float32)
    key = jax.random.PRNGKey(shards)
    s_emu = jax.vmap(lambda _: kvplane.init(cfg))(jnp.arange(shards))
    mesh = mesh_lib.make_far_mesh(shards)
    s_dev = _put_far(s_emu, mesh)
    dec = (kvplane.jitted_sharded_decode(cfg),
           kvplane.jitted_sharded_decode(cfg, mesh=mesh))
    app = jax.jit(functools.partial(kvplane.append_sharded, cfg))
    for t in range(18):
        key, k1, k2, k3 = jax.random.split(key, 4)
        kn = jax.random.normal(k1, (1, 1, 8), jnp.float32)
        vn = jax.random.normal(k2, (1, 1, 8), jnp.float32)
        L = jnp.array([t], jnp.int32)
        s_emu = app(s_emu, kn, vn, L)
        s_dev = app(s_dev, kn, vn, L)
        if t % 3 == 2:
            q = jax.random.normal(k3, (1, 1, 8), jnp.float32)
            o_emu, s_emu = dec[0](s_emu, q, L + 1)
            o_dev, s_dev = dec[1](s_dev, q, L + 1)
            np.testing.assert_array_equal(np.asarray(o_emu),
                                          np.asarray(o_dev),
                                          err_msg=f"decode t={t}")
    assert_trees_equal(s_emu, s_dev, "kv state")


# --------------------------------------------------------------------------
# overlap-pipelined exchange vs the serial schedule
# --------------------------------------------------------------------------

def _exchange_pair(shards, budget=3, pcfg=GCFG):
    """Matched configs differing ONLY in the exchange schedule; the small
    budget forces multiple (spilling) rounds through the pipeline."""
    mk = lambda ex: shardplane.make_config(pcfg, shards, R,
                                           per_shard_budget=budget,
                                           exchange=ex)
    return mk("overlap"), mk("serial")


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_overlap_vs_serial_bitwise(shards):
    """The pipelined schedule reorders collective *issue*, not values:
    rows, served channel, final state and every stat match the serial
    schedule bit-for-bit through spilling rounds, interleaved updates AND
    a shard-targeted outage window (oracle backend) — and the outage's
    failures stay attributed to the dead shard only."""
    tgt = min(1, shards - 1)
    # interleaved accesses+updates each bump the step clock, so the window
    # spans the whole run to guarantee it covers a fetch-bearing access
    sched = faults.Schedule(seed=7, outages=((1, 11, tgt),))
    pcfg = dataclasses.replace(GCFG, faults=sched)
    co, cs = _exchange_pair(shards, pcfg=pcfg)
    assert co.rounds > 1                    # the fori steady state engages
    data = initial_data()
    so, ss = shardplane.create(co, data), shardplane.create(cs, data)
    ao = shardplane.jitted_access(co, with_served=True)
    a_s = shardplane.jitted_access(cs, with_served=True)
    uo, us = shardplane.jitted_update(co), shardplane.jitted_update(cs)
    rng = np.random.default_rng(61)
    for t, ids in enumerate(workload("skewed", shards, steps=5, seed=61)):
        jids = jnp.asarray(ids)
        so, ro, svo = ao(so, jids)
        ss, rs, svs = a_s(ss, jids)
        np.testing.assert_array_equal(np.asarray(ro), np.asarray(rs),
                                      err_msg=f"rows t={t}")
        np.testing.assert_array_equal(np.asarray(svo), np.asarray(svs),
                                      err_msg=f"served t={t}")
        rows = rng.normal(size=(shards, R, D)).astype(np.float32)
        so = uo(so, jids, jnp.asarray(rows))
        ss = us(ss, jids, jnp.asarray(rows))
    assert int(shardplane.stats_total(so).ingress_spills) > 0
    per_shard = np.asarray(so.stats.fetch_failures).reshape(-1)
    assert per_shard[tgt] > 0, "outage window never fired"
    assert per_shard.sum() == per_shard[tgt], "outage leaked across shards"
    assert_trees_equal(so, ss, f"overlap-vs-serial shards={shards}")


@needs8
def test_shard_map_overlap_vs_serial_bitwise():
    """Overlap == serial on real (simulated) devices too, spill path
    included — and each still matches its oracle (4-way agreement)."""
    shards = 4
    co, cs = _exchange_pair(shards)
    mesh = mesh_lib.make_far_mesh(shards)
    data = initial_data()
    s_oracle = shardplane.create(co, data)
    so, ss = _put_far(s_oracle, mesh), _put_far(s_oracle, mesh)
    ao = shardplane.jitted_access(co, mesh=mesh, with_served=True)
    a_s = shardplane.jitted_access(cs, mesh=mesh, with_served=True)
    a_e = shardplane.jitted_access(co, with_served=True)
    for t, ids in enumerate(workload("skewed", shards, steps=4, seed=71)):
        jids = jnp.asarray(ids)
        so, ro, svo = ao(so, jids)
        ss, rs, svs = a_s(ss, jids)
        s_oracle, re, sve = a_e(s_oracle, jids)
        np.testing.assert_array_equal(np.asarray(ro), np.asarray(rs),
                                      err_msg=f"rows t={t}")
        np.testing.assert_array_equal(np.asarray(ro), np.asarray(re),
                                      err_msg=f"oracle rows t={t}")
        np.testing.assert_array_equal(np.asarray(svo), np.asarray(svs))
    assert_trees_equal(so, ss, "shard_map overlap-vs-serial")
    assert_trees_equal(so, s_oracle, "shard_map overlap vs oracle")
    assert int(shardplane.stats_total(so).ingress_spills) > 0


def _count_a2a(jaxpr):
    """Recursively count all_to_all equations (sub-jaxprs included)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "all_to_all":
            n += 1
        for v in eqn.params.values():
            for u in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(u, "jaxpr"):             # ClosedJaxpr
                    n += _count_a2a(u.jaxpr)
                elif hasattr(u, "eqns"):            # raw Jaxpr
                    n += _count_a2a(u)
    return n


@needs8
def test_overlap_halves_collectives_per_round():
    """The fused payloads cut the exchange from 3 collectives per round
    (ids, counts, rows) to 2 (fused ingress, fused egress) — verified on
    the traced shard_map program, loop-free (rounds=1) and pipelined
    (rounds=6: serial unrolls 3/round; overlap keeps 2 in the fori body
    plus one ingress prologue + one egress epilogue)."""
    shards = 4
    mesh = mesh_lib.make_far_mesh(shards)
    data = initial_data()

    def count(budget, exchange):
        scfg = shardplane.make_config(GCFG, shards, R,
                                      per_shard_budget=budget,
                                      exchange=exchange)
        states = _put_far(shardplane.create(scfg, data), mesh)
        ids = jnp.zeros((shards, R), jnp.int32)
        fn = shardplane.jitted_access(scfg, mesh=mesh)
        return _count_a2a(jax.make_jaxpr(fn)(states, ids).jaxpr)

    assert count(None, "serial") == 3       # one round: ids + counts + rows
    assert count(None, "overlap") == 2      # fused ingress + fused egress
    rounds = shardplane.make_config(GCFG, shards, R,
                                    per_shard_budget=3).rounds
    assert count(3, "serial") == 3 * rounds
    assert count(3, "overlap") == 4         # 2 steady-state + 2 pro/epilogue


@needs8
def test_engine_sharded_mesh_serves_plain_rows():
    """End to end: a 4-shard engine on a far mesh returns the same rows as
    the plain single-device engine (read path + maintenance running)."""
    from repro.serving.engine import Engine, EngineConfig
    data = initial_data()
    B = 64
    mk = lambda **kw: Engine(EngineConfig(plane="hybrid", batch=B,
                                          evac_every=8, epoch_every=10,
                                          dispatch="sync", **kw),
                             GCFG, data,
                             **({} if "shards" not in kw else
                                {"mesh": mesh_lib.make_far_mesh(
                                    kw["shards"])}))
    e0, e4 = mk(), mk(shards=4)
    rng = np.random.default_rng(51)
    for _ in range(10):
        ids = rng.integers(0, O, size=B)
        np.testing.assert_array_equal(np.asarray(e0.serve_batch(ids)),
                                      np.asarray(e4.serve_batch(ids)))
    r = e4.run([], 0.0)
    assert r["stats"]["hits"] + r["stats"]["misses"] == 10 * B
