"""Model-layer correctness: decode == teacher-forced forward per family,
chunked-vs-full attention, MoE routing, recurrence continuation."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchConfig, ShapeConfig
from repro.models import api, attention, lm, ssm
from repro.models.common import init_params

RNG = np.random.RandomState(0)
BASE = dict(d_model=64, n_heads=4, vocab=256, dtype=jnp.float32)


def _roundtrip(cfg, T=10, B=2):
    params = init_params(api.model_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    ref, _ = lm.forward(cfg, params, toks)
    shape = ShapeConfig("t", 64, B, "decode")
    state = api.init_decode_state(cfg, shape)
    step = jax.jit(api.decode_step(cfg, shape))
    outs = []
    for t in range(T):
        state, lg = step(params, state, toks[:, t])
        outs.append(lg)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


def test_decode_equals_forward_dense():
    _roundtrip(ArchConfig(name="d", family="dense", n_layers=2,
                          n_kv_heads=2, d_ff=128, **BASE))


def test_decode_equals_forward_moe():
    _roundtrip(ArchConfig(name="m", family="moe", n_layers=2, n_kv_heads=2,
                          d_ff=128, moe_experts=4, moe_topk=2,
                          moe_capacity=8.0, **BASE))


def test_decode_equals_forward_xlstm():
    _roundtrip(ArchConfig(name="x", family="ssm", n_layers=4, n_kv_heads=4,
                          d_ff=0, **BASE))


def test_decode_equals_forward_zamba():
    _roundtrip(ArchConfig(name="z", family="hybrid", n_layers=38,
                          n_kv_heads=4, d_ff=128, ssm_state=8, **BASE))


def test_chunked_attention_equals_full():
    q = jnp.asarray(RNG.randn(2, 64, 8, 32), jnp.float32)
    k = jnp.asarray(RNG.randn(2, 64, 2, 32), jnp.float32)
    v = jnp.asarray(RNG.randn(2, 64, 2, 32), jnp.float32)
    for window in (0, 24):
        a = attention.chunked_attention(q, k, v, causal=True, window=window,
                                        chunk_q=16, chunk_k=16)
        b = attention.full_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_linear_rnn_chunked_equals_sequential():
    B, S, H, dk, dv = 2, 32, 3, 8, 16
    q = jnp.asarray(RNG.randn(B, S, H, dk), jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, H, dk), jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, H, dv), jnp.float32)
    la = jnp.asarray(-np.abs(RNG.rand(B, S, H)), jnp.float32)
    y, sf = ssm.chunked_linear_rnn(q, k, v, la, chunk=8)
    s = np.zeros((B, H, dk, dv)); ys = np.zeros((B, S, H, dv))
    for t in range(S):
        a = np.exp(np.asarray(la)[:, t])
        s = a[..., None, None] * s + np.einsum(
            "bhd,bhv->bhdv", np.asarray(k)[:, t], np.asarray(v)[:, t])
        ys[:, t] = np.einsum("bhd,bhdv->bhv", np.asarray(q)[:, t], s)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), s, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blk,defs", [
    ("mamba2", lambda d: ssm.mamba2_defs(d, 8, jnp.float32)),
    ("mlstm", lambda d: ssm.mlstm_defs(d, 4, jnp.float32)),
    ("slstm", lambda d: ssm.slstm_defs(d, 4, jnp.float32)),
])
def test_recurrent_blocks_state_continuation(blk, defs):
    @dataclasses.dataclass(frozen=True)
    class C:
        ssm_state: int = 8
        n_heads: int = 4
    cfg, d = C(), 32
    p = init_params(defs(d), jax.random.PRNGKey(1))
    x = jnp.asarray(RNG.randn(2, 16, d) * 0.1, jnp.float32)
    fn = {"mamba2": partial(ssm.mamba2_block, chunk=4),
          "mlstm": partial(ssm.mlstm_block, chunk=4),
          "slstm": ssm.slstm_block}[blk]
    y_full, _ = fn(p, x, cfg)
    y_a, st = fn(p, x[:, :12], cfg)
    y_b, _ = fn(p, x[:, 12:], cfg, st)
    np.testing.assert_allclose(np.asarray(y_full[:, 12:]), np.asarray(y_b),
                               rtol=2e-4, atol=2e-4)


def test_moe_load_balance_loss_positive():
    from repro.models import mlp
    defs = mlp.moe_defs(16, 32, 4, True, jnp.float32)
    p = init_params(defs, jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.randn(2, 8, 16), jnp.float32)
    out, aux = mlp.moe(p, x, n_experts=4, topk=2)
    assert out.shape == (2, 8, 16)
    assert float(aux) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz at balance
