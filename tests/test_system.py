"""End-to-end behaviour tests for the paper's system.

These check the paper's HEADLINE CLAIMS at reduced scale:
  1. training works (loss decreases on a small LM),
  2. the hybrid plane adapts: paging on sequential, objects on random,
  3. the hybrid plane's far-memory traffic is never worse than BOTH
     baselines on their respective bad patterns (the Fig. 4 qualitative
     claim), and its egress is page-granular (cheap) while the object
     plane pays the object-LRU scan cost (Fig. 1c).
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core import (PlaneConfig, access, baselines, create, evacuate,
                        jitted_access, jitted_evacuate, jitted_object_access,
                        jitted_paging_access)
from repro.data import kvworkload
from repro.models import api
from repro.optim import get_optimizer


def test_training_reduces_loss():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                     dtype=jnp.float32, remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = get_optimizer("adamw", lr=lambda s: 1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(api.make_train_step(cfg, opt))
    # a memorizable repeating sequence
    toks = jnp.tile(jnp.arange(16, dtype=jnp.int32), (4, 4))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    step = jnp.zeros((), jnp.int32)
    losses = []
    for _ in range(30):
        params, opt_state, step, loss, gnorm = step_fn(
            params, opt_state, step, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def _run_plane(fn, cfg, data, workload):
    s = create(cfg, data)
    for ids in workload:
        s, _ = fn(s, jnp.asarray(ids, jnp.int32))
    return jax.device_get(s.stats), s


def _traffic(cfg, stats):
    """Modeled far-memory bytes moved (the paper's I/O amplification)."""
    return (stats.page_ins * cfg.page_bytes
            + stats.obj_ins * cfg.row_bytes
            + stats.dirty_page_outs * cfg.page_bytes
            + stats.obj_outs * cfg.row_bytes)


def test_hybrid_traffic_adapts_to_pattern():
    cfg = PlaneConfig(num_objs=512, obj_dim=16, page_objs=8, num_frames=24,
                      num_vpages=200)
    data = jnp.zeros((512, 16))

    seq = list(kvworkload.scan(512, 16, steps=60))
    rnd = list(kvworkload.uniform(512, 16, steps=60))

    hyb = jitted_access(cfg)
    pag = jitted_paging_access(cfg)

    # sequential: hybrid ~ paging (fetches pages, no object churn)
    st_h, _ = _run_plane(hyb, cfg, data, seq)
    st_p, _ = _run_plane(pag, cfg, data, seq)
    assert int(st_h.obj_ins) == 0
    assert _traffic(cfg, st_h) <= 1.2 * _traffic(cfg, st_p)

    # random: hybrid engages the object path and beats paging's
    # I/O amplification
    st_h, _ = _run_plane(hyb, cfg, data, rnd)
    st_p, _ = _run_plane(pag, cfg, data, rnd)
    assert int(st_h.obj_ins) > 0
    assert _traffic(cfg, st_h) < _traffic(cfg, st_p)


def test_object_plane_pays_lru_scan_cost():
    """Fig 1c: object-granular egress costs an LRU scan over objects;
    page-granular egress scans only frames."""
    cfg = PlaneConfig(num_objs=512, obj_dim=16, page_objs=8, num_frames=16,
                      num_vpages=200)
    data = jnp.zeros((512, 16))
    rnd = list(kvworkload.uniform(512, 16, steps=40, seed=5))
    st_o, _ = _run_plane(jitted_object_access(cfg), cfg, data, rnd)
    st_h, _ = _run_plane(jitted_access(cfg), cfg, data, rnd)
    assert int(st_o.lru_scans) > 10 * cfg.num_objs   # repeated full scans
    assert int(st_h.lru_scans) == 0                  # Atlas: no object LRU


def test_evacuation_segregates_hot_objects():
    """The evacuator groups recently-accessed (access-bit) objects into
    contiguous pages — the locality-manufacturing step (paper §4.3).

    Note: in a read-only workload the hybrid plane *drains* runtime-path
    pages object-by-object (their garbage never becomes local), so we force
    an evacuation pass (threshold < 0) over the fill pages to exercise the
    hot/cold segregation machinery directly."""
    from repro.core import check_invariants, peek
    cfg = PlaneConfig(num_objs=256, obj_dim=8, page_objs=8, num_frames=20,
                      num_vpages=120)
    data = jnp.arange(256 * 8, dtype=jnp.float32).reshape(256, 8)
    s = create(cfg, data)
    acc = jitted_access(cfg)
    # churn: random singles fill the log pages with mixed-heat objects
    for ids in kvworkload.uniform(256, 12, steps=25, seed=4):
        s, _ = acc(s, jnp.asarray(ids))
    # mark a known hot set (fresh access bits)
    s = s._replace(access=jnp.zeros_like(s.access))
    hot = jnp.arange(0, 64, 2, dtype=jnp.int32)
    s, _ = acc(s, hot)
    s2 = jitted_evacuate(cfg, garbage_threshold=-1.0, max_pages=64)(s)
    assert int(s2.stats.evac_moved) > int(s.stats.evac_moved)
    assert all(check_invariants(cfg, s2).values())
    np.testing.assert_allclose(np.asarray(peek(cfg, s2, jnp.arange(256))),
                               np.asarray(data))
    # hot objects that were moved share pages exclusively with other hot
    # objects (segregation): check page purity for pages hosting hot objs
    sn = jax.device_get(s2)
    hot_set = set(np.asarray(hot).tolist())
    pages_of_hot = {int(sn.obj_loc[o]) // cfg.page_objs for o in hot_set}
    mixed = 0
    for v in pages_of_hot:
        occupants = [o for o in sn.obj_of[v] if o >= 0]
        others = [o for o in occupants if o not in hot_set]
        mixed += len(others)
    total = int((np.asarray(sn.obj_of[list(pages_of_hot)]) >= 0).sum())
    purity = 1 - mixed / max(total, 1)
    assert purity > 0.5, purity
