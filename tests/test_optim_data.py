"""Optimizers, grad accumulation, compression, synthetic data."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import DataConfig, batch_for_step
from repro.optim import (Adafactor, AdamW, accumulated_value_and_grad,
                         compression, get_optimizer)


def _descends(opt):
    w = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = opt.init(w)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for step in range(60):
        g = jax.grad(loss)(w)
        w, state, _ = opt.update(g, state, w, jnp.asarray(step))
    return float(loss(w))


def test_adamw_descends():
    assert _descends(AdamW(lr=lambda s: 0.1)) < 1e-2


def test_adafactor_descends():
    assert _descends(Adafactor(lr=lambda s: 0.1)) < 1e-1


def test_grad_accumulation_matches_full_batch():
    w = {"w": jnp.ones((4, 3))}
    batch = jnp.asarray(np.random.RandomState(0).randn(8, 4), jnp.float32)

    def loss(p, b):
        return jnp.mean((b @ p["w"]) ** 2)

    l1, g1 = jax.value_and_grad(loss)(w, batch)
    l2, g2 = accumulated_value_and_grad(loss, 4)(w, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               rtol=1e-5)


def test_compression_error_feedback_converges():
    g = jnp.asarray(np.random.RandomState(1).randn(64) * 0.1, jnp.float32)
    ef = compression.EFState(jnp.zeros(64))
    acc_true = np.zeros(64)
    acc_deq = np.zeros(64)
    for _ in range(50):
        q, s, r = compression.quantize(g, ef.residual)
        ef = compression.EFState(r)
        acc_true += np.asarray(g)
        acc_deq += np.asarray(q, np.float32) * float(s)
    # error feedback: accumulated dequantized grads track the true sum
    np.testing.assert_allclose(acc_deq, acc_true, atol=0.05)


def test_data_step_indexed_determinism():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
    a = batch_for_step(cfg, 17)
    b = batch_for_step(cfg, 17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(cfg, 18)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 0
    assert np.all(a["labels"][:, -1] == -1)


def test_prefetcher_matches_direct_and_survives_seek():
    from repro.data.pipeline import Prefetcher
    from repro.data.synthetic import DataConfig, batch_for_step
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    fn = lambda s: batch_for_step(cfg, s)
    pf = Prefetcher(fn, start_step=0, depth=2)
    try:
        for s in range(5):
            got = pf.get(expect_step=s)
            np.testing.assert_array_equal(got["tokens"], fn(s)["tokens"])
        # seek (restart at a different step): deterministic rebuild
        got = pf.get(expect_step=42)
        np.testing.assert_array_equal(got["tokens"], fn(42)["tokens"])
    finally:
        pf.close()
