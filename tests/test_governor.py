"""Epoch governor + prefetch planner + background evacuation tests.

Covers the adaptive control plane: epoch CAR decay flipping PSF online
(no page-out involved), the traffic-balancing threshold governor, prefetch
coverage/accuracy counter consistency, and the plan/execute evacuation
split (sliced background evacuation preserves data + invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PlaneConfig, advance_epoch, check_invariants, create,
                        execute_evacuate, jitted_access, jitted_advance_epoch,
                        jitted_evacuate, peek, plan_evacuate)
from repro.core import state as state_lib
from repro.core.layout import CAR_THR_MAX, CAR_THR_MIN


def mk(num_objs=96, obj_dim=4, page_objs=8, num_frames=6, num_vpages=40, **kw):
    kw.setdefault("kernel_impl", "ref")
    cfg = PlaneConfig(num_objs=num_objs, obj_dim=obj_dim, page_objs=page_objs,
                      num_frames=num_frames, num_vpages=num_vpages, **kw)
    data = jnp.arange(num_objs * obj_dim, dtype=jnp.float32
                      ).reshape(num_objs, obj_dim)
    return cfg, data, create(cfg, data)


# --------------------------------------------------------------------------
# epoch profiling: online PSF recomputation from decayed CAR
# --------------------------------------------------------------------------

def test_epoch_flips_psf_online_without_pageout():
    """Sustained dense access moves a page runtime->paging across epochs;
    sustained sparse access moves it back — all with zero page-outs (the
    frames cover the working set), i.e. the flips are the governor's."""
    cfg, data, s = mk(num_frames=16, psf_init_paging=False)
    acc = jitted_access(cfg)
    ep = jitted_advance_epoch(cfg)

    # runtime-path warmup: the 8 objects of page 0 move to one fill page
    ids = jnp.arange(8, dtype=jnp.int32)
    s, _ = acc(s, ids)
    v = int(s.obj_loc[0]) // cfg.page_objs
    assert not bool(s.psf[v])                       # born on the runtime path
    outs0 = int(s.stats.page_outs)

    # dense epochs: every card of the page touched -> window CAR = 1
    for _ in range(5):
        s, _ = acc(s, ids)
        s = ep(s)
    assert bool(s.psf[v]), float(s.car_ema[v])      # flipped to paging online
    assert float(s.car_ema[v]) >= float(s.car_thr)

    # sparse epochs: one card per window -> EMA decays back down
    one = jnp.zeros((8,), jnp.int32) + ids[0]
    for _ in range(6):
        s, _ = acc(s, one)
        s = ep(s)
    assert not bool(s.psf[v]), float(s.car_ema[v])  # and back to runtime
    assert int(s.stats.page_outs) == outs0          # no page-out involved
    assert int(s.stats.epochs) == 11
    assert int(s.stats.psf_to_paging) >= 1
    assert int(s.stats.psf_to_runtime) >= 1


def test_epoch_clears_cat_window():
    cfg, data, s = mk(num_frames=16)
    acc = jitted_access(cfg)
    s, _ = acc(s, jnp.arange(8, dtype=jnp.int32))
    assert bool(s.cat.any())
    s = advance_epoch(cfg, s)
    assert not bool(s.cat.any())
    assert int(s.epoch) == 1


def test_governor_threshold_tracks_traffic_imbalance():
    """Paging-dominated epochs raise the threshold, object-dominated epochs
    lower it, and the walk clamps to [CAR_THR_MIN, CAR_THR_MAX]."""
    cfg, data, s0 = mk()

    def with_traffic(s, page_ins, obj_ins):
        return s._replace(stats=state_lib.bump(
            s.stats, page_ins=jnp.asarray(page_ins, jnp.int32),
            obj_ins=jnp.asarray(obj_ins, jnp.int32)))

    s = advance_epoch(cfg, with_traffic(s0, 100, 0))
    assert float(s.car_thr) > cfg.car_threshold     # paging dominates: raise
    up = float(s.car_thr)
    s = advance_epoch(cfg, with_traffic(s, 0, 100))
    assert float(s.car_thr) < up                    # objects dominate: lower
    # no traffic -> no movement
    thr = float(s.car_thr)
    s = advance_epoch(cfg, s)
    assert float(s.car_thr) == pytest.approx(thr)
    # clamping at both ends
    for _ in range(40):
        s = advance_epoch(cfg, with_traffic(s, 1000, 0))
    assert float(s.car_thr) == pytest.approx(CAR_THR_MAX)
    for _ in range(40):
        s = advance_epoch(cfg, with_traffic(s, 0, 1000))
    assert float(s.car_thr) == pytest.approx(CAR_THR_MIN)


def test_adaptive_threshold_drives_pageout_psf():
    """page_out consults the ADAPTIVE threshold: with the governor pinned
    at CAR_THR_MAX a fully-touched page still drops to the runtime path at
    page-out (CAR 1.0 >= 1.0 keeps paging; just below must not)."""
    cfg, data, s = mk(car_threshold=0.8)
    acc = jitted_access(cfg)
    s, _ = acc(s, jnp.arange(7, dtype=jnp.int32))   # 7 of 8 cards on page 0
    s = s._replace(car_thr=jnp.asarray(1.0, jnp.float32))
    from repro.core import evict_all
    s = jax.jit(lambda s: evict_all(cfg, s))(s)
    assert not bool(s.psf[0])                       # 7/8 < 1.0 -> runtime


# --------------------------------------------------------------------------
# prefetch counters
# --------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", ["sequential", "majority"])
def test_prefetch_counters_consistent_with_plans(prefetch):
    """prefetch_used never exceeds prefetch_issued, issued pages are a
    subset of page_ins, and the standing `prefetched` bits account for
    exactly the issued-but-not-yet-used-or-evicted remainder."""
    cfg, data, s = mk(num_frames=12, readahead=2, prefetch=prefetch,
                      prefetch_budget=4)
    acc = jitted_access(cfg)
    for start in range(0, 96, 16):                   # marching scan
        s, _ = acc(s, jnp.arange(start, start + 16, dtype=jnp.int32) % 96)
    issued = int(s.stats.prefetch_issued)
    used = int(s.stats.prefetch_used)
    assert issued > 0                                # the planner engaged
    assert used > 0                                  # and the scan used it
    assert used <= issued
    assert issued <= int(s.stats.page_ins)
    outstanding = int(np.asarray(s.prefetched).sum())
    assert outstanding <= issued - used
    assert all(check_invariants(cfg, s).values())


def test_prefetch_never_evicts_target_or_pinned():
    """A prefetch must not push out a page this batch needs: with the pool
    full of target pages, the plan schedules no prefetches at all."""
    from repro.core import batch as batch_lib
    cfg, data, s = mk(num_frames=6, readahead=2, prefetch="sequential",
                      prefetch_budget=4)
    acc = jitted_access(cfg)
    ids = jnp.arange(48, dtype=jnp.int32)            # 6 pages = whole pool
    s, _ = acc(s, ids)
    plan = batch_lib.plan_access(cfg, s, ids)
    pf = np.asarray(plan.pg_fetch)[np.asarray(plan.pg_is_pf)]
    assert np.all(pf == -1)                          # nothing usable: dropped


# --------------------------------------------------------------------------
# background evacuation: plan/execute split
# --------------------------------------------------------------------------

def test_sliced_evacuation_preserves_data_and_invariants():
    """Incremental evac_budget-page slices (clear_access=False) must reach
    the same safety bar as the foreground call: data intact, invariants
    hold, garbage actually reclaimed."""
    cfg, data, s = mk(num_frames=8)
    acc = jitted_access(cfg)
    truth = np.asarray(data)
    rng = np.random.RandomState(3)
    moved0 = 0
    for step in range(24):
        ids = jnp.asarray(rng.choice(96, 12), jnp.int32)
        s, _ = acc(s, ids)
        if step % 2 == 1:                            # a slice per gap
            # threshold -1: every local page qualifies, so the tiny slices
            # are guaranteed to exercise compaction continuously
            plan = plan_evacuate(cfg, s, garbage_threshold=-1.0, max_pages=2)
            s = execute_evacuate(cfg, s, plan, garbage_threshold=-1.0,
                                 clear_access=False)
            assert all(check_invariants(cfg, s).values()), step
            np.testing.assert_array_equal(
                np.asarray(peek(cfg, s, jnp.arange(96, dtype=jnp.int32))),
                truth)
    assert int(s.stats.evac_pages) > 0
    assert bool(s.access.any())                      # slices kept the bits
    # the round boundary clears them
    s = execute_evacuate(cfg, s, plan_evacuate(cfg, s, -1.0, 2), -1.0,
                         clear_access=True)
    assert not bool(s.access.any())


def test_foreground_evacuate_is_plan_execute_composition():
    cfg, data, s = mk(num_frames=8)
    acc = jitted_access(cfg)
    rng = np.random.RandomState(5)
    for _ in range(12):
        s, _ = acc(s, jnp.asarray(rng.choice(96, 12), jnp.int32))
    a = jitted_evacuate(cfg, garbage_threshold=0.05)(s)
    b = execute_evacuate(cfg, s, plan_evacuate(cfg, s, 0.05), 0.05)
    for field in a._fields:
        for x, y in zip(jax.tree.leaves(getattr(a, field)),
                        jax.tree.leaves(getattr(b, field))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=field)
