"""Equivalence tests: the vectorized batch engine vs the scalar reference
oracle (``mode="reference"``), which replays the identical access plan one
state update at a time.

The two executors must agree on EVERYTHING — returned rows byte-for-byte
and the full PlaneState pytree (stats, psf, obj_loc, occupancy, pins, ...)
— on random, skewed and sequential workloads, for all three planes, and
through mixed access/update/evacuate interleavings (with the structural
invariants checked after every maintenance step)."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PlaneConfig, baselines, check_invariants, create,
                        evacuate, jitted_access, jitted_evacuate,
                        jitted_object_access, jitted_paging_access,
                        jitted_update, peek)
from repro.core import batch as batch_lib


def mk(num_objs=96, obj_dim=4, page_objs=8, num_frames=6, num_vpages=40, **kw):
    kw.setdefault("kernel_impl", "ref")
    cfg = PlaneConfig(num_objs=num_objs, obj_dim=obj_dim, page_objs=page_objs,
                      num_frames=num_frames, num_vpages=num_vpages, **kw)
    data = jnp.arange(num_objs * obj_dim, dtype=jnp.float32
                      ).reshape(num_objs, obj_dim)
    return cfg, data, create(cfg, data)


def assert_states_equal(sa, sb, ctx=""):
    for field in sa._fields:
        for x, y in zip(jax.tree.leaves(getattr(sa, field)),
                        jax.tree.leaves(getattr(sb, field))):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"PlaneState.{field} diverged {ctx}")


def workload(kind: str, n_objs: int, batch: int, steps: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    for i in range(steps):
        if kind == "random":
            ids = rng.randint(0, n_objs, size=batch)
        elif kind == "skewed":       # zipf-ish: hot head + heavy duplicates
            z = rng.zipf(1.5, size=batch)
            ids = np.clip(z - 1, 0, n_objs - 1)
        elif kind == "sequential":
            ids = (np.arange(batch) + i * batch) % n_objs
        else:
            raise ValueError(kind)
        yield jnp.asarray(ids, jnp.int32)


@pytest.mark.parametrize("prefetch", ["sequential", "majority"])
@pytest.mark.parametrize("kind", ["random", "skewed", "sequential"])
def test_access_equivalence(kind, prefetch):
    """Batch vs reference on the IDENTICAL plan — including the prefetch
    candidate section, for both the sequential-window and the
    majority-stride planner."""
    cfg, data, s0 = mk(readahead=2, prefetch=prefetch, prefetch_budget=4)
    accB = jitted_access(cfg, "batch")
    accR = jitted_access(cfg, "reference")
    sb = sr = s0
    for step, ids in enumerate(workload(kind, 96, 16, 12, seed=1)):
        sb, rb = accB(sb, ids)
        sr, rr = accR(sr, ids)
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr),
                                      err_msg=f"rows diverged at step {step}")
        # both executors must also return ground truth
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(data[ids]))
        assert_states_equal(sb, sr, f"({kind}, step {step})")
    assert int(sb.stats.misses) > 0          # the sweep exercised both paths
    assert all(check_invariants(cfg, sb).values())


@pytest.mark.parametrize("plane", ["paging", "paging-majority", "object"])
def test_baseline_equivalence(plane):
    kw = (dict(prefetch="majority", prefetch_budget=4)
          if plane == "paging-majority" else {})
    cfg, data, s0 = mk(readahead=2, **kw)
    mkjit = (jitted_object_access if plane == "object"
             else jitted_paging_access)
    fB = mkjit(cfg, "batch")
    fR = mkjit(cfg, "reference")
    sb = sr = s0
    for step, ids in enumerate(workload("random", 96, 16, 10, seed=2)):
        sb, rb = fB(sb, ids)
        sr, rr = fR(sr, ids)
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(data[ids]))
        assert_states_equal(sb, sr, f"({plane}, step {step})")


def test_mixed_ops_equivalence_and_invariants():
    """Mixed access/update/evacuate sweep: full-state agreement plus a
    ``check_invariants`` pass after every maintenance step."""
    cfg, data, s0 = mk(num_frames=8)
    accB = jitted_access(cfg, "batch")
    accR = jitted_access(cfg, "reference")
    updB = jitted_update(cfg, "batch")
    updR = jitted_update(cfg, "reference")
    evac = jitted_evacuate(cfg, garbage_threshold=0.05)
    truth = np.asarray(data).copy()

    rng = np.random.RandomState(7)
    sb = sr = s0
    for step in range(20):
        op = step % 4
        if op in (0, 1):                        # access (duplicates allowed)
            ids = jnp.asarray(rng.randint(0, 96, 12), jnp.int32)
            sb, rb = accB(sb, ids)
            sr, rr = accR(sr, ids)
            np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr))
            np.testing.assert_array_equal(np.asarray(rb), truth[np.asarray(ids)])
        elif op == 2:                           # update (last write wins)
            ids_np = rng.randint(0, 96, 10)
            rows = rng.randn(10, 4).astype(np.float32)
            ids = jnp.asarray(ids_np, jnp.int32)
            sb = updB(sb, ids, jnp.asarray(rows))
            sr = updR(sr, ids, jnp.asarray(rows))
            truth[ids_np] = rows                # numpy assignment: last wins
        else:                                   # evacuate (shared impl)
            sb = evac(sb)
            sr = evac(sr)
            assert all(check_invariants(cfg, sb).values())
        assert_states_equal(sb, sr, f"(mixed, step {step})")

    # final ground truth after the whole interleaving
    all_ids = jnp.arange(96, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(peek(cfg, sb, all_ids)), truth)
    assert all(check_invariants(cfg, sb).values())


def test_evacuation_under_memory_pressure_preserves_data():
    """Regression: a retired evacuation cursor must stay pinned until the
    compact writes land — with a tiny frame pool, the other stream's
    fresh-page allocation could otherwise evict it mid-evacuation and
    silently corrupt an unrelated frame."""
    from repro.core import (jitted_access, jitted_evacuate, jitted_update)
    cfg = PlaneConfig(num_objs=128, obj_dim=4, page_objs=4, num_frames=5,
                      num_vpages=80, kernel_impl="ref")
    data = jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4)
    s = create(cfg, data)
    truth = np.asarray(data).copy()
    acc, upd = jitted_access(cfg), jitted_update(cfg)
    ev = jitted_evacuate(cfg, garbage_threshold=-1.0, max_pages=8)
    rng = np.random.RandomState(11)
    for step in range(45):
        ids_np = rng.randint(0, 128, 10)
        ids = jnp.asarray(ids_np, jnp.int32)
        if step % 3 == 2:
            rows = rng.randn(10, 4).astype(np.float32)
            s = upd(s, ids, jnp.asarray(rows))
            truth[ids_np] = rows
        else:
            s, r = acc(s, ids)
            np.testing.assert_array_equal(np.asarray(r), truth[ids_np])
        if step % 5 == 4:
            s = ev(s)
            assert all(check_invariants(cfg, s).values()), step
            got = np.asarray(peek(cfg, s, jnp.arange(128, dtype=jnp.int32)))
            np.testing.assert_array_equal(got, truth,
                                          err_msg=f"corruption at step {step}")


# --------------------------------------------------------------------------
# serve-path planes: kvplane / expertplane batch-vs-reference equivalence
# --------------------------------------------------------------------------

from repro.core import expertplane as ep  # noqa: E402
from repro.core import kvplane  # noqa: E402


def _kv_prefill(cfg, seed, magnet=True):
    """Fully-written far tier with optional magnet rows (skewed attention
    -> runtime-path PSF flips + packed fetches)."""
    rng = np.random.RandomState(seed)
    s = kvplane.init(cfg)
    KVH, P, Dh = cfg.kv_heads, cfg.page_tokens, cfg.head_dim
    pages = cfg.batch * cfg.num_pages
    k = rng.randn(KVH, pages, P, Dh).astype(np.float32)
    if magnet:
        k[:, 3, 2] = 4.0
        k[:, pages // 2, 1] = -4.0
    v = rng.randn(KVH, pages, P, Dh).astype(np.float32)
    return s._replace(k_slab=jnp.asarray(k), v_slab=jnp.asarray(v),
                      kmax=jnp.asarray(k.max(axis=2)),
                      kmin=jnp.asarray(k.min(axis=2)))


def assert_kv_states_equal(sa, sb, ctx=""):
    for field in sa._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(sa, field)), np.asarray(getattr(sb, field)),
            err_msg=f"KVPlaneState.{field} diverged {ctx}")


@pytest.mark.parametrize("qscale", [0.3, 3.0])  # random vs skewed selections
def test_kvplane_attend_sparse_equivalence(qscale):
    """attend_sparse: the batched fetch executor and the scalar oracle
    replay the identical plan — outputs and the full KVPlaneState must
    agree bit-for-bit through a frame-churning decode sweep."""
    cfg = kvplane.KVPlaneConfig(kv_heads=2, head_dim=8, page_tokens=4,
                                num_pages=12, num_frames=5, batch=2,
                                sparse_topk=4, fetch_budget=2,
                                car_threshold=0.5, dtype=jnp.float32)
    sb = _kv_prefill(cfg, 1)
    sr = _kv_prefill(cfg, 1)
    lengths = jnp.full((2,), cfg.num_pages * cfg.page_tokens, jnp.int32)
    stepB = jax.jit(partial(kvplane.attend_sparse, cfg, mode="batch"))
    stepR = jax.jit(partial(kvplane.attend_sparse, cfg, mode="reference"))
    rng = np.random.RandomState(0)
    for i in range(12):
        q = jnp.asarray(rng.randn(2, 4, 8) * qscale, jnp.float32)
        ob, sb = stepB(sb, q, lengths)
        orr, sr = stepR(sr, q, lengths)
        np.testing.assert_array_equal(np.asarray(ob), np.asarray(orr),
                                      err_msg=f"rows diverged at step {i}")
        assert_kv_states_equal(sb, sr, f"(qscale={qscale}, step {i})")
    # the sweep exercised real churn: some pages were fetched and evicted
    assert int(np.asarray(sb.frame_page >= 0).sum()) > 0


@pytest.mark.parametrize("prefetch", ["sequential", "majority"])
def test_kvplane_attend_sparse_equivalence_with_lookahead(prefetch):
    """Decode lookahead (the prefetch section of the kv fetch plan) keeps
    the batch executor bit-identical to the scalar replay."""
    cfg = kvplane.KVPlaneConfig(kv_heads=2, head_dim=8, page_tokens=4,
                                num_pages=12, num_frames=8, batch=2,
                                sparse_topk=4, fetch_budget=2,
                                car_threshold=0.5, dtype=jnp.float32,
                                prefetch=prefetch, prefetch_budget=2)
    sb = _kv_prefill(cfg, 2)
    sr = _kv_prefill(cfg, 2)
    lengths = jnp.full((2,), cfg.num_pages * cfg.page_tokens, jnp.int32)
    stepB = jax.jit(partial(kvplane.attend_sparse, cfg, mode="batch"))
    stepR = jax.jit(partial(kvplane.attend_sparse, cfg, mode="reference"))
    rng = np.random.RandomState(4)
    for i in range(10):
        q = jnp.asarray(rng.randn(2, 4, 8), jnp.float32)
        ob, sb = stepB(sb, q, lengths)
        orr, sr = stepR(sr, q, lengths)
        np.testing.assert_array_equal(np.asarray(ob), np.asarray(orr),
                                      err_msg=f"rows diverged at step {i}")
        assert_kv_states_equal(sb, sr, f"({prefetch}, step {i})")


def test_kvplane_sharded_append_attend_equivalence():
    """Sharded decode (vmapped batch executor) with append/attend
    interleavings: both executors must agree on output and state."""
    cfg = kvplane.KVPlaneConfig(kv_heads=1, head_dim=8, page_tokens=4,
                                num_pages=8, num_frames=3, batch=1,
                                sparse_topk=3, fetch_budget=2,
                                car_threshold=0.5, dtype=jnp.float32)
    D = 2
    states = jax.vmap(lambda _: kvplane.init(cfg))(jnp.arange(D))
    stB = stR = states
    lengths = jnp.asarray([0], jnp.int32)
    app = jax.jit(partial(kvplane.append_sharded, cfg))
    decB = jax.jit(partial(kvplane.sharded_sparse_decode, cfg, mode="batch"))
    decR = jax.jit(partial(kvplane.sharded_sparse_decode, cfg,
                           mode="reference"))
    rng = np.random.RandomState(5)
    for t in range(40):
        kn = jnp.asarray(rng.randn(1, 1, 8), jnp.float32)
        vn = jnp.asarray(rng.randn(1, 1, 8), jnp.float32)
        stB = app(stB, kn, vn, lengths)
        stR = app(stR, kn, vn, lengths)
        lengths = lengths + 1
        if t % 3 == 2:
            q = jnp.asarray(rng.randn(1, 1, 8), jnp.float32)
            ob, stB = decB(stB, q, lengths)
            orr, stR = decR(stR, q, lengths)
            np.testing.assert_array_equal(np.asarray(ob), np.asarray(orr))
            assert_kv_states_equal(stB, stR, f"(sharded, t={t})")


def test_kvplane_plan_victims_compact_onto_real_fetches():
    """Regression: victims must be compacted onto VALID fetch entries.
    With seq0's wanted pages all resident (pinned) and seq1 holding the
    only real misses, the no-op plan slots of seq0 must not absorb the
    coldest (free) frame while seq1's fetches evict pinned wanted-resident
    frames."""
    cfg = kvplane.KVPlaneConfig(kv_heads=1, head_dim=4, page_tokens=2,
                                num_pages=8, num_frames=5, batch=2,
                                sparse_topk=2, fetch_budget=2,
                                dtype=jnp.float32)
    s = kvplane.init(cfg)
    # frames 0..3 host seq0 pages 0..3; frame 4 free and coldest
    pt = jnp.full((2, 8), -1, jnp.int32)
    for pg, f in enumerate(range(4)):
        pt = pt.at[0, pg].set(f)
    s = s._replace(page_table=pt,
                   frame_page=jnp.asarray([0, 1, 2, 3, -1], jnp.int32),
                   clock=jnp.asarray([5, 6, 7, 8, 0], jnp.int32))
    tops = jnp.asarray([[0, 1], [4, 5]], jnp.int32)   # seq0 resident, seq1 missing
    plan = kvplane.plan_fetch(cfg, s, tops)
    page = np.asarray(plan.page)
    victim = np.asarray(plan.victim)
    real = victim[page >= 0]
    assert 4 in real, (page, victim)       # the free frame is actually used
    # no wanted-resident (pinned) frame is evicted for these fetches
    assert not set(real.tolist()) & {0, 1}, (page, victim)


def test_expertplane_moe_decode_equivalence():
    """moe_decode: batched expert fetch vs scalar oracle — identical y and
    full ExpertPlaneState through a hot-set-churning sweep."""
    rng = np.random.RandomState(3)
    cfg = ep.ExpertPlaneConfig(n_experts=16, d_model=8, d_ff=12, hot_slots=6,
                               topk=2, fetch_budget=3, dtype=jnp.float32)
    wi = jnp.asarray(rng.randn(16, 8, 12), jnp.float32)
    wg = jnp.asarray(rng.randn(16, 8, 12), jnp.float32)
    wo = jnp.asarray(rng.randn(16, 12, 8), jnp.float32)
    router = jnp.asarray(rng.randn(8, 16), jnp.float32)
    stepB = jax.jit(partial(ep.moe_decode, cfg, mode="batch"))
    stepR = jax.jit(partial(ep.moe_decode, cfg, mode="reference"))
    sb = sr = ep.init(cfg)
    churned = 0
    for i in range(15):
        x = jnp.asarray(rng.randn(3, 8), jnp.float32)
        yb, sb = stepB(sb, router, x, wi, wg, wo)
        yr, sr = stepR(sr, router, x, wi, wg, wo)
        np.testing.assert_array_equal(np.asarray(yb), np.asarray(yr),
                                      err_msg=f"y diverged at step {i}")
        for field in sb._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(sb, field)), np.asarray(getattr(sr, field)),
                err_msg=f"ExpertPlaneState.{field} diverged at step {i}")
        churned = max(churned, int((np.asarray(sb.slot_of) >= 0).sum()))
    assert churned > 0          # the sweep actually exercised the fetch path


def test_interpret_kernels_match_reference():
    """CPU CI path: the Pallas kernel bodies executed in interpret mode
    must produce the same plane trajectory as the jnp reference kernels."""
    import dataclasses
    cfg, data, s0 = mk(readahead=1)
    cfgI = dataclasses.replace(cfg, kernel_impl="interpret")
    a_ref = jitted_access(cfg)
    a_int = jitted_access(cfgI)
    s1 = s2 = s0
    for ids in workload("random", 96, 16, 4, seed=3):
        s1, r1 = a_ref(s1, ids)
        s2, r2 = a_int(s2, ids)
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        assert_states_equal(s1, s2, "(interpret vs ref)")
