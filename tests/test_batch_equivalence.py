"""Equivalence tests: the vectorized batch engine vs the scalar reference
oracle (``mode="reference"``), which replays the identical access plan one
state update at a time.

The two executors must agree on EVERYTHING — returned rows byte-for-byte
and the full PlaneState pytree (stats, psf, obj_loc, occupancy, pins, ...)
— on random, skewed and sequential workloads, for all three planes, and
through mixed access/update/evacuate interleavings (with the structural
invariants checked after every maintenance step)."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PlaneConfig, baselines, check_invariants, create,
                        evacuate, jitted_access, jitted_evacuate,
                        jitted_object_access, jitted_paging_access,
                        jitted_update, peek)
from repro.core import batch as batch_lib


def mk(num_objs=96, obj_dim=4, page_objs=8, num_frames=6, num_vpages=40, **kw):
    kw.setdefault("kernel_impl", "ref")
    cfg = PlaneConfig(num_objs=num_objs, obj_dim=obj_dim, page_objs=page_objs,
                      num_frames=num_frames, num_vpages=num_vpages, **kw)
    data = jnp.arange(num_objs * obj_dim, dtype=jnp.float32
                      ).reshape(num_objs, obj_dim)
    return cfg, data, create(cfg, data)


def assert_states_equal(sa, sb, ctx=""):
    for field in sa._fields:
        for x, y in zip(jax.tree.leaves(getattr(sa, field)),
                        jax.tree.leaves(getattr(sb, field))):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"PlaneState.{field} diverged {ctx}")


def workload(kind: str, n_objs: int, batch: int, steps: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    for i in range(steps):
        if kind == "random":
            ids = rng.randint(0, n_objs, size=batch)
        elif kind == "skewed":       # zipf-ish: hot head + heavy duplicates
            z = rng.zipf(1.5, size=batch)
            ids = np.clip(z - 1, 0, n_objs - 1)
        elif kind == "sequential":
            ids = (np.arange(batch) + i * batch) % n_objs
        else:
            raise ValueError(kind)
        yield jnp.asarray(ids, jnp.int32)


@pytest.mark.parametrize("kind", ["random", "skewed", "sequential"])
def test_access_equivalence(kind):
    cfg, data, s0 = mk(readahead=2)
    accB = jitted_access(cfg, "batch")
    accR = jitted_access(cfg, "reference")
    sb = sr = s0
    for step, ids in enumerate(workload(kind, 96, 16, 12, seed=1)):
        sb, rb = accB(sb, ids)
        sr, rr = accR(sr, ids)
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr),
                                      err_msg=f"rows diverged at step {step}")
        # both executors must also return ground truth
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(data[ids]))
        assert_states_equal(sb, sr, f"({kind}, step {step})")
    assert int(sb.stats.misses) > 0          # the sweep exercised both paths
    assert all(check_invariants(cfg, sb).values())


@pytest.mark.parametrize("plane", ["paging", "object"])
def test_baseline_equivalence(plane):
    cfg, data, s0 = mk(readahead=2)
    mkjit = (jitted_paging_access if plane == "paging"
             else jitted_object_access)
    fB = mkjit(cfg, "batch")
    fR = mkjit(cfg, "reference")
    sb = sr = s0
    for step, ids in enumerate(workload("random", 96, 16, 10, seed=2)):
        sb, rb = fB(sb, ids)
        sr, rr = fR(sr, ids)
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(data[ids]))
        assert_states_equal(sb, sr, f"({plane}, step {step})")


def test_mixed_ops_equivalence_and_invariants():
    """Mixed access/update/evacuate sweep: full-state agreement plus a
    ``check_invariants`` pass after every maintenance step."""
    cfg, data, s0 = mk(num_frames=8)
    accB = jitted_access(cfg, "batch")
    accR = jitted_access(cfg, "reference")
    updB = jitted_update(cfg, "batch")
    updR = jitted_update(cfg, "reference")
    evac = jitted_evacuate(cfg, garbage_threshold=0.05)
    truth = np.asarray(data).copy()

    rng = np.random.RandomState(7)
    sb = sr = s0
    for step in range(20):
        op = step % 4
        if op in (0, 1):                        # access (duplicates allowed)
            ids = jnp.asarray(rng.randint(0, 96, 12), jnp.int32)
            sb, rb = accB(sb, ids)
            sr, rr = accR(sr, ids)
            np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr))
            np.testing.assert_array_equal(np.asarray(rb), truth[np.asarray(ids)])
        elif op == 2:                           # update (last write wins)
            ids_np = rng.randint(0, 96, 10)
            rows = rng.randn(10, 4).astype(np.float32)
            ids = jnp.asarray(ids_np, jnp.int32)
            sb = updB(sb, ids, jnp.asarray(rows))
            sr = updR(sr, ids, jnp.asarray(rows))
            truth[ids_np] = rows                # numpy assignment: last wins
        else:                                   # evacuate (shared impl)
            sb = evac(sb)
            sr = evac(sr)
            assert all(check_invariants(cfg, sb).values())
        assert_states_equal(sb, sr, f"(mixed, step {step})")

    # final ground truth after the whole interleaving
    all_ids = jnp.arange(96, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(peek(cfg, sb, all_ids)), truth)
    assert all(check_invariants(cfg, sb).values())


def test_evacuation_under_memory_pressure_preserves_data():
    """Regression: a retired evacuation cursor must stay pinned until the
    compact writes land — with a tiny frame pool, the other stream's
    fresh-page allocation could otherwise evict it mid-evacuation and
    silently corrupt an unrelated frame."""
    from repro.core import (jitted_access, jitted_evacuate, jitted_update)
    cfg = PlaneConfig(num_objs=128, obj_dim=4, page_objs=4, num_frames=5,
                      num_vpages=80, kernel_impl="ref")
    data = jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4)
    s = create(cfg, data)
    truth = np.asarray(data).copy()
    acc, upd = jitted_access(cfg), jitted_update(cfg)
    ev = jitted_evacuate(cfg, garbage_threshold=-1.0, max_pages=8)
    rng = np.random.RandomState(11)
    for step in range(45):
        ids_np = rng.randint(0, 128, 10)
        ids = jnp.asarray(ids_np, jnp.int32)
        if step % 3 == 2:
            rows = rng.randn(10, 4).astype(np.float32)
            s = upd(s, ids, jnp.asarray(rows))
            truth[ids_np] = rows
        else:
            s, r = acc(s, ids)
            np.testing.assert_array_equal(np.asarray(r), truth[ids_np])
        if step % 5 == 4:
            s = ev(s)
            assert all(check_invariants(cfg, s).values()), step
            got = np.asarray(peek(cfg, s, jnp.arange(128, dtype=jnp.int32)))
            np.testing.assert_array_equal(got, truth,
                                          err_msg=f"corruption at step {step}")


def test_interpret_kernels_match_reference():
    """CPU CI path: the Pallas kernel bodies executed in interpret mode
    must produce the same plane trajectory as the jnp reference kernels."""
    import dataclasses
    cfg, data, s0 = mk(readahead=1)
    cfgI = dataclasses.replace(cfg, kernel_impl="interpret")
    a_ref = jitted_access(cfg)
    a_int = jitted_access(cfgI)
    s1 = s2 = s0
    for ids in workload("random", 96, 16, 4, seed=3):
        s1, r1 = a_ref(s1, ids)
        s2, r2 = a_int(s2, ids)
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        assert_states_equal(s1, s2, "(interpret vs ref)")
