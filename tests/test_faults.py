"""Chaos tests: the deterministic fault model end to end.

Covers the four layers the fault schedule threads through:

  * the schedule itself — host/device bit-agreement, seed determinism;
  * the batch plane — null-schedule bit-identity with the fault-free
    engine, batch-vs-reference equivalence *under* faults for all three
    planes, structural invariants through a mixed chaos soak, and the
    no-partial-write guarantee (a faulted fetch/update leaves both tiers
    untouched);
  * the sharded exchange — per-shard fault streams, outage windows that
    hit only the scheduled shard, same-seed determinism (oracle path;
    the 8-device shard_map equivalence rides in tests/test_sharded.py's
    environment and is gated the same way);
  * the serving engine — fault-free robust engine bit-identical to the
    plain one, retries that converge, deadline shedding, the circuit
    breaker tripping into degraded paging-local mode and recovering,
    bounded latency-tracker memory, and counter determinism;
  * egress faults + the per-shard breaker (DESIGN.md §6c) — remote-WRITE
    failures (eviction writeback, update slab writes, evacuation moves,
    KV appends) masked at plan time so neither tier ever sees a partial
    write, slow-but-alive windows that add latency without feeding the
    breaker, and the per-shard breaker isolating a single-shard outage
    while healthy shards keep serving the fast path bit-identically.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PlaneConfig, baselines, check_invariants, create,
                        evacuate, faults, peek)
from repro.core import batch as batch_lib
from repro.core import expertplane
from repro.core import shardplane
from repro.core import state as state_lib
from repro.runtime.orchestrator import FailureInjector
from repro.serving.engine import Engine, EngineConfig, LatencyTracker

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def mk(num_objs=96, obj_dim=4, page_objs=8, num_frames=6, num_vpages=40,
       **kw):
    kw.setdefault("kernel_impl", "ref")
    cfg = PlaneConfig(num_objs=num_objs, obj_dim=obj_dim,
                      page_objs=page_objs, num_frames=num_frames,
                      num_vpages=num_vpages, **kw)
    data = jnp.arange(num_objs * obj_dim, dtype=jnp.float32
                      ).reshape(num_objs, obj_dim)
    return cfg, data, create(cfg, data)


def assert_states_equal(sa, sb, ctx=""):
    for field in sa._fields:
        for x, y in zip(jax.tree.leaves(getattr(sa, field)),
                        jax.tree.leaves(getattr(sb, field))):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"PlaneState.{field} diverged {ctx}")


def workload(n_objs, batch, steps, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(steps):
        yield jnp.asarray(rng.randint(0, n_objs, size=batch), jnp.int32)


# ---------------------------------------------------------------------------
# the schedule itself
# ---------------------------------------------------------------------------

def test_schedule_host_device_agreement():
    sched = faults.Schedule(seed=11, fail_prob=0.3,
                            outages=((5, 8, 1),), fail_at=(12,))
    keys = np.arange(64, dtype=np.int32)
    for tick in [1, 3, 5, 7, 9, 12, 20]:
        for shard in [0, 1]:
            dev = np.asarray(sched.fetch_fail(tick, jnp.asarray(keys), shard))
            host = np.array([sched.fails(tick, int(k), shard) for k in keys])
            np.testing.assert_array_equal(dev, host,
                                          err_msg=f"tick={tick} sh={shard}")


def test_schedule_determinism_and_seeds():
    a = faults.Schedule(seed=1, fail_prob=0.25)
    b = faults.Schedule(seed=1, fail_prob=0.25)
    c = faults.Schedule(seed=2, fail_prob=0.25)
    keys = jnp.arange(256)
    for tick in range(4):
        ma, mb = a.fetch_fail(tick, keys), b.fetch_fail(tick, keys)
        assert jnp.array_equal(ma, mb)
    assert any(not jnp.array_equal(a.fetch_fail(t, keys),
                                   c.fetch_fail(t, keys))
               for t in range(4)), "different seeds never diverged"
    # shards get decorrelated streams
    assert not jnp.array_equal(a.fetch_fail(1, keys, 0),
                               a.fetch_fail(1, keys, 1))


def test_null_schedule_is_inert():
    assert not faults.NULL.active
    assert not faults.Schedule(spike_prob=0.5, spike_us=100.0).active
    assert not np.any(np.asarray(faults.NULL.fetch_fail(3, jnp.arange(8))))
    assert faults.NULL.spike(3) == 0.0


# ---------------------------------------------------------------------------
# batch plane under faults
# ---------------------------------------------------------------------------

def test_null_faults_bit_identical_plane():
    """faults=NULL wired into the config is bit-identical to faults=None."""
    cfg0, _, s0 = mk()
    cfgN, _, sN = mk(faults=faults.NULL)
    for ids in workload(96, 16, 12, seed=3):
        p0 = batch_lib.plan_access(cfg0, s0, ids)
        pN = batch_lib.plan_access(cfgN, sN, ids)
        assert jnp.array_equal(p0.served, pN.served)
        assert jnp.array_equal(p0.served, ids >= 0)
        assert int(pN.n_failed) == 0
        s0, r0 = batch_lib.execute_access(cfg0, s0, ids, p0)
        sN, rN = batch_lib.execute_access(cfgN, sN, ids, pN)
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(rN))
    assert_states_equal(s0, sN, "null-schedule plane")


@pytest.mark.parametrize("plane", ["hybrid", "paging", "object"])
def test_batch_vs_reference_under_faults(plane):
    """The vectorized executor and the scalar oracle agree bit-for-bit on
    the SAME fault-holed plan — rows, served masks and full state."""
    sched = faults.Schedule(seed=5, fail_prob=0.25, outages=((4, 7, -1),))
    cfg, _, sb = mk(faults=sched)
    sr = sb
    fn = {"hybrid": batch_lib.access,
          "paging": batch_lib.paging_access,
          "object": baselines.object_access}[plane]
    seed = {"hybrid": 1, "paging": 2, "object": 3}[plane]
    for i, ids in enumerate(workload(96, 16, 15, seed=seed)):
        sb, rb = fn(cfg, sb, ids, mode="batch")
        sr, rr = fn(cfg, sr, ids, mode="reference")
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(rr),
                                      err_msg=f"{plane} rows step {i}")
    assert_states_equal(sb, sr, f"{plane} under faults")
    assert int(sb.stats.fetch_failures) > 0, "schedule never fired"


def test_chaos_soak_invariants_and_determinism():
    """Mixed access/update/evacuate under a failure schedule: structural
    invariants hold at every step and the whole trajectory is a pure
    function of the seed."""
    sched = faults.Schedule(seed=9, fail_prob=0.2, outages=((6, 10, -1),))

    def soak():
        cfg, data, s = mk(faults=sched)
        rng = np.random.RandomState(1)
        for i in range(24):
            ids = jnp.asarray(rng.randint(0, 96, size=16), jnp.int32)
            op = i % 3
            if op == 0:
                s, _ = batch_lib.access(cfg, s, ids)
            elif op == 1:
                rows = jnp.asarray(
                    rng.standard_normal((16, cfg.obj_dim)), jnp.float32)
                s = batch_lib.update(cfg, s, ids, rows)
            else:
                s = evacuate(cfg, s)
            check_invariants(cfg, s)
        return cfg, s

    cfg, sa = soak()
    _, sb = soak()
    assert_states_equal(sa, sb, "chaos soak replay")
    assert int(sa.stats.fetch_failures) > 0


def test_faulted_update_writes_nothing():
    """No-partial-write: at a tick where every remote fetch fails, an
    update of remote objects mutates NEITHER tier — a later read sees the
    pre-fault values exactly."""
    # the plane's device tick for the k-th access/update is k+1
    sched = faults.Schedule(seed=0, fail_at=(1,))
    cfg, data, s = mk(faults=sched)
    ids = jnp.arange(16, dtype=jnp.int32)        # all remote in fresh state
    before = peek(cfg, s, ids)
    new_rows = jnp.full((16, cfg.obj_dim), 123.0, jnp.float32)
    s = batch_lib.update(cfg, s, ids, new_rows)  # tick 1: everything faults
    check_invariants(cfg, s)
    np.testing.assert_array_equal(np.asarray(peek(cfg, s, ids)),
                                  np.asarray(before))
    # tick 2 is clean: the retry lands the write
    s = batch_lib.update(cfg, s, ids, new_rows)
    np.testing.assert_array_equal(np.asarray(peek(cfg, s, ids)),
                                  np.asarray(new_rows))


# ---------------------------------------------------------------------------
# expert plane under faults (plan-time masking, same discipline as kvplane)
# ---------------------------------------------------------------------------

def _mk_expert(faults_sched=None):
    cfg = expertplane.ExpertPlaneConfig(
        n_experts=32, d_model=8, d_ff=16, hot_slots=8, topk=2,
        fetch_budget=4, dtype=jnp.float32, kernel_impl="ref",
        faults=faults_sched)
    key = jax.random.PRNGKey(17)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    slabs = (jax.random.normal(k1, (32, 8, 16), jnp.float32),
             jax.random.normal(k2, (32, 8, 16), jnp.float32),
             jax.random.normal(k3, (32, 16, 8), jnp.float32))
    router = jax.random.normal(k4, (8, 32), jnp.float32)
    return cfg, expertplane.init(cfg), router, slabs


def test_expertplane_fault_masks_plan_no_slot_claimed():
    """A faulted expert fetch drops out of the PLAN: it claims no slot and
    displaces nothing (plan-time masking, not a partial execute)."""
    sched = faults.Schedule(seed=2, fail_prob=1.0)   # every fetch faults
    cfg, s, _, slabs = _mk_expert(sched)
    needed = jnp.zeros((32,), bool).at[jnp.arange(4)].set(True)
    plan = expertplane.plan_fetch(cfg, s, needed)
    assert np.all(np.asarray(plan.expert) == -1), "faulted fetch kept"
    s2 = expertplane.ensure_resident(cfg, s, needed, *slabs)
    assert np.all(np.asarray(s2.slot_of) == -1), "faulted fetch claimed slot"
    # null schedule is inert: the same plan with faults off fetches
    cfg0, s0, _, _ = _mk_expert(faults.NULL)
    plan0 = expertplane.plan_fetch(cfg0, s0, needed)
    assert np.asarray(plan0.expert >= 0).sum() == 4


def test_expertplane_batch_vs_reference_under_faults():
    """Both fetch executors replay the identical fault-holed plan: decode
    outputs and full state match bit-for-bit, while the schedule visibly
    perturbs residency vs a fault-free twin."""
    sched = faults.Schedule(seed=9, fail_prob=0.3)
    cfg, s0, router, slabs = _mk_expert(sched)
    cfg_ok, _, _, _ = _mk_expert(None)
    sb = sr = sn = s0
    key = jax.random.PRNGKey(3)
    masked = False
    for t in range(12):
        key, kx = jax.random.split(key)
        x = jax.random.normal(kx, (4, 8), jnp.float32)
        yb, sb = expertplane.moe_decode(cfg, sb, router, x, *slabs,
                                        mode="batch")
        yr, sr = expertplane.moe_decode(cfg, sr, router, x, *slabs,
                                        mode="reference")
        _, sn = expertplane.moe_decode(cfg_ok, sn, router, x, *slabs,
                                       mode="batch")
        np.testing.assert_array_equal(np.asarray(yb), np.asarray(yr),
                                      err_msg=f"decode step {t}")
        masked = masked or not np.array_equal(np.asarray(sb.slot_of),
                                              np.asarray(sn.slot_of))
    for f in sb._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(sb, f)), np.asarray(getattr(sr, f)),
            err_msg=f"ExpertPlaneState.{f} diverged under faults")
    assert masked, "fault schedule never masked an expert fetch"


# ---------------------------------------------------------------------------
# sharded exchange under faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_soak_served_and_determinism(shards):
    sched = faults.Schedule(seed=13, fail_prob=0.2)
    base, _, _ = mk(num_objs=96 * shards, num_frames=6 * shards,
                    num_vpages=40 * shards, faults=sched)
    scfg = shardplane.make_config(base, shards, 16, plane="hybrid")

    def soak():
        data = jnp.arange(base.num_objs * base.obj_dim, dtype=jnp.float32
                          ).reshape(base.num_objs, base.obj_dim)
        states = shardplane.create(scfg, data)
        rng = np.random.RandomState(2)
        sv_all = []
        for _ in range(10):
            ids = jnp.asarray(
                rng.randint(0, base.num_objs, size=(shards, 16)), jnp.int32)
            states, rows, sv = shardplane.access(scfg, states, ids,
                                                 with_served=True)
            sv_all.append(np.asarray(sv))
            assert rows.shape == (shards, 16, base.obj_dim)
        for k in range(shards):
            check_invariants(scfg.shard, jax.tree.map(
                lambda x: x[k], states))
        return states, np.stack(sv_all)

    states_a, sv_a = soak()
    states_b, sv_b = soak()
    np.testing.assert_array_equal(sv_a, sv_b)
    assert_states_equal(states_a, states_b, f"sharded soak S={shards}")
    assert int(jnp.sum(states_a.stats.fetch_failures)) > 0
    assert not sv_a.all(), "no request was ever fault-masked"


@needs8
@pytest.mark.parametrize("shards", [2, 4])
def test_shard_map_served_channel_matches_oracle(shards):
    """The with_served shard_map program is bit-identical to the vmap
    oracle under faults — rows, served verdicts and full state."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import mesh as mesh_lib

    sched = faults.Schedule(seed=17, fail_prob=0.2, outages=((3, 6, 1),))
    base, _, _ = mk(num_objs=96 * shards, num_frames=6 * shards,
                    num_vpages=40 * shards, faults=sched)
    scfg = shardplane.make_config(base, shards, 16, plane="hybrid")
    data = jnp.arange(base.num_objs * base.obj_dim, dtype=jnp.float32
                      ).reshape(base.num_objs, base.obj_dim)
    s_emu = shardplane.create(scfg, data)
    mesh = mesh_lib.make_far_mesh(shards)
    s_dev = jax.device_put(s_emu, jax.tree.map(
        lambda _: NamedSharding(mesh, P("far")), s_emu))
    a_emu = shardplane.jitted_access(scfg, with_served=True)
    a_dev = shardplane.jitted_access(scfg, mesh=mesh, with_served=True)
    rng = np.random.RandomState(6)
    for t in range(6):
        ids = jnp.asarray(rng.randint(0, base.num_objs, size=(shards, 16)),
                          jnp.int32)
        s_emu, r_emu, v_emu = a_emu(s_emu, ids)
        s_dev, r_dev, v_dev = a_dev(s_dev, ids)
        np.testing.assert_array_equal(np.asarray(r_emu), np.asarray(r_dev),
                                      err_msg=f"rows t={t}")
        np.testing.assert_array_equal(np.asarray(v_emu), np.asarray(v_dev),
                                      err_msg=f"served t={t}")
    assert_states_equal(s_emu, s_dev, f"shard_map served S={shards}")
    assert int(jnp.sum(s_emu.stats.fetch_failures)) > 0


def test_sharded_outage_hits_only_scheduled_shard():
    sched = faults.Schedule(seed=3, outages=((1, 12, 1),))
    base, _, _ = mk(num_objs=192, num_frames=12, num_vpages=80,
                    faults=sched)
    scfg = shardplane.make_config(base, 2, 16, plane="hybrid")
    data = jnp.arange(192 * 4, dtype=jnp.float32).reshape(192, 4)
    states = shardplane.create(scfg, data)
    rng = np.random.RandomState(4)
    for _ in range(8):
        ids = jnp.asarray(rng.randint(0, 192, size=(2, 16)), jnp.int32)
        states, _, _ = shardplane.access(scfg, states, ids, with_served=True)
    per_shard = np.asarray(states.stats.fetch_failures)
    assert per_shard[1] > 0, "outage shard saw no failures"
    assert per_shard[0] == 0, "outage leaked onto a healthy shard"


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def mk_engine_pair(plane="hybrid", robust_kw=None, n_objs=256, frames=12,
                   batch=16, dispatch="sync", shards=1, faults_sched=None):
    pcfg = PlaneConfig(num_objs=n_objs, obj_dim=8, page_objs=8,
                       num_frames=frames, num_vpages=3 * (n_objs // 8),
                       kernel_impl="ref")
    data = jnp.arange(n_objs * 8, dtype=jnp.float32).reshape(n_objs, 8)
    ecfg = EngineConfig(plane=plane, batch=batch, dispatch=dispatch,
                        shards=shards, faults=faults_sched,
                        **(robust_kw or {}))
    return Engine(ecfg, pcfg, data), pcfg, data


@pytest.mark.parametrize("plane", ["hybrid", "paging", "object"])
def test_engine_fault_free_robust_bit_identical(plane):
    """All robustness features armed + a null schedule == today's engine:
    same rows, same plane state, same device stats."""
    eng_r, pcfg, data = mk_engine_pair(
        plane, faults_sched=faults.NULL,
        robust_kw=dict(max_retries=3, deadline_us=1e9,
                       breaker_threshold=0.5))
    eng_0 = Engine(EngineConfig(plane=plane, batch=16, dispatch="sync"),
                   pcfg, data)
    rng = np.random.RandomState(0)
    for _ in range(10):
        ids = rng.randint(0, 256, size=16).astype(np.int32)
        r0 = eng_0.serve_batch(ids)
        rr = eng_r.serve_batch(ids)
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(rr))
    assert_states_equal(eng_0.state, eng_r.state, f"engine {plane}")
    c = eng_r.counters
    assert c["fetch_retries"] == 0 and c["shed_requests"] == 0
    assert c["degraded_ticks"] == 0 and not eng_r.breaker_open
    assert c["served"] == 160


def test_engine_retries_recover_goodput():
    sched = faults.Schedule(seed=7, fail_prob=0.2)
    eng, _, data = mk_engine_pair(faults_sched=sched,
                                  robust_kw=dict(max_retries=6))
    wl = [np.random.RandomState(s).randint(0, 256, size=16).astype(np.int32)
          for s in range(30)]
    out = eng.run(wl)
    c = out["counters"]
    assert c["fetch_retries"] > 0
    assert c["served"] + c["shed_requests"] == 30 * 16
    assert c["served"] >= int(0.99 * 30 * 16)
    assert out["stats"]["fetch_failures"] > 0
    assert out["goodput_rps"] <= out["throughput_rps"]
    assert out["latency"]["n"] == c["served"]


def test_engine_retry_serves_correct_value():
    """A retried GET returns the same bytes a fault-free serve would."""
    sched = faults.Schedule(seed=2, fail_at=(2,))   # warmup=tick1; tick2 dies
    eng, _, data = mk_engine_pair(faults_sched=sched,
                                  robust_kw=dict(max_retries=2))
    # ids 16..31: two pages the warmup tick (which touches page 0) never
    # faulted in, so every request here needs a remote fetch
    ids = np.arange(16, 32, dtype=np.int32)
    eng.serve_batch(ids)            # tick 2: every fetch faults -> queued
    assert len(eng._retryq) == 16
    eng.flush_retries()             # tick 3 is clean
    assert not eng._retryq
    assert eng.counters["served"] == 16
    rows = eng.serve_batch(ids)     # now local: must be the true rows
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(data)[ids])


def test_engine_deadline_shed_at_admission():
    eng, _, _ = mk_engine_pair(
        faults_sched=faults.NULL,
        robust_kw=dict(deadline_us=1000.0, max_retries=1))
    ids = np.arange(16, dtype=np.int32)
    rows = eng.submit(ids, t_sched=time.time() - 1.0)   # 1s late: shed
    eng.drain()
    assert rows.shape == (16, 8)
    assert eng.counters["shed_requests"] == 16
    assert eng.counters["deadline_misses"] >= 16
    assert eng.counters["served"] == 0


def test_engine_breaker_degrades_and_recovers():
    sched = faults.Schedule(seed=7, outages=((10, 40, -1),))
    kw = dict(max_retries=1, breaker_threshold=0.5, breaker_probe_every=4)

    def drive():
        eng, _, _ = mk_engine_pair(faults_sched=sched, robust_kw=kw)
        tripped = False
        for s in range(60):
            ids = np.random.RandomState(s).randint(
                0, 256, size=16).astype(np.int32)
            eng.submit(ids)
            eng.drain()
            tripped |= eng.breaker_open
        eng.flush_retries()
        return eng, tripped

    eng, tripped = drive()
    assert tripped, "breaker never opened during the outage"
    assert not eng.breaker_open, "breaker failed to close after recovery"
    assert eng.counters["breaker_trips"] >= 1
    assert eng.counters["degraded_ticks"] > 0
    assert eng.counters["served"] > 0
    # same seed, same trajectory -> identical chaos accounting
    eng2, _ = drive()
    assert eng.counters == eng2.counters


def test_engine_short_batch_single_compile():
    eng, _, data = mk_engine_pair(dispatch="pipelined")
    full = np.arange(16, dtype=np.int32)
    short = np.arange(5, dtype=np.int32)
    eng.serve_batch(full)
    rows = eng.serve_batch(short)
    assert rows.shape == (5, 8)
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(data)[short])
    # the -1 padding keeps one compiled (plan, execute) pair per engine
    for fn in (eng._plan, eng._exec):
        if hasattr(fn, "_cache_size"):
            assert fn._cache_size() == 1


def test_latency_tracker_bounded_memory():
    lt = LatencyTracker(capacity=512)
    rng = np.random.RandomState(0)
    for _ in range(50):
        lt.record_us(rng.rand(400) * 100.0)
    assert lt.n == 20_000
    assert len(lt.lat_us) == 512            # retained set stays bounded
    s = lt.summary()
    assert s["n"] == 20_000
    assert 0.0 < s["p50_us"] < 100.0 and s["p99_us"] <= 100.0
    assert abs(s["mean_us"] - 50.0) < 5.0   # exact streaming mean
    # legacy scalar API still works and zero-arg construction is preserved
    lt2 = LatencyTracker()
    lt2.record(0.0, 1e-3, 3)
    assert lt2.summary()["n"] == 3 and lt2.percentile(50) == pytest.approx(1e3)


def test_engine_watchdog_raises_instead_of_hanging():
    eng, _, _ = mk_engine_pair(robust_kw=dict(watchdog_s=0.05),
                               faults_sched=faults.NULL)

    class NeverReady:
        def is_ready(self):
            return False

        def block_until_ready(self):  # pragma: no cover
            raise AssertionError("watchdog must fire before blocking")

    with pytest.raises(TimeoutError):
        eng._wait_ready(NeverReady())


# ---------------------------------------------------------------------------
# orchestrator unification
# ---------------------------------------------------------------------------

def test_failure_injector_rides_the_schedule():
    # legacy API: explicit steps, fire-once each
    inj = FailureInjector(fail_at_steps=[7, 13])
    fired = []
    for step in range(20):
        try:
            inj.check(step)
        except RuntimeError:
            fired.append(step)
            inj.check(step)             # restart of the same step: no re-fail
    assert fired == [7, 13] and inj.failures == 2
    assert inj.schedule.fail_at == (7, 13)

    # seeded schedule: deterministic step loss, still fire-once
    sched = faults.Schedule(seed=21, fail_prob=0.3)
    a = FailureInjector(schedule=sched)
    b = FailureInjector(schedule=sched)
    hits_a = [s for s in range(40) if _trips(a, s)]
    hits_b = [s for s in range(40) if _trips(b, s)]
    assert hits_a == hits_b and 0 < len(hits_a) < 40
    assert a.failures == len(hits_a)

    # both together: extra explicit steps merge into the schedule
    c = FailureInjector(fail_at_steps=[5], schedule=faults.Schedule(seed=21))
    assert _trips(c, 5) and c.failures == 1


def _trips(inj, step):
    try:
        inj.check(step)
        return False
    except RuntimeError:
        return True


# ---------------------------------------------------------------------------
# egress faults (remote-WRITE failures, DESIGN.md §6c)
# ---------------------------------------------------------------------------

def test_schedule_egress_host_device_agreement():
    sched = faults.Schedule(seed=11, egress_prob=0.3, egress_window=(2, 9),
                            outages=((5, 8, 1),), fail_at=(12,))
    keys = np.arange(64, dtype=np.int32)
    for tick in [1, 3, 5, 7, 9, 12, 20]:
        for shard in [0, 1]:
            dev = np.asarray(sched.egress_fail(tick, jnp.asarray(keys),
                                               shard))
            host = np.array([sched.fails_egress(tick, int(k), shard)
                             for k in keys])
            np.testing.assert_array_equal(dev, host,
                                          err_msg=f"tick={tick} sh={shard}")


def test_schedule_egress_stream_independent_of_fetch():
    """The egress salt decorrelates write faults from read faults: a seed
    that loses a fetch need not lose the writeback of the same key."""
    sched = faults.Schedule(seed=5, fail_prob=0.3, egress_prob=0.3)
    keys = jnp.arange(512)
    assert any(not jnp.array_equal(sched.fetch_fail(t, keys),
                                   sched.egress_fail(t, keys))
               for t in range(4)), "egress stream mirrors the fetch stream"
    # outages and fail_at kill BOTH directions (a dead shard can't write)
    out = faults.Schedule(seed=5, outages=((3, 6, -1),))
    assert bool(np.asarray(out.egress_fail(4, jnp.asarray([7]))).all())
    assert out.egress_active and not faults.NULL.egress_active


def test_schedule_slowdowns_latency_only():
    """Slow-but-alive windows are pure latency: they never appear in any
    failure predicate (slow != dead — the breaker must not trip)."""
    sched = faults.Schedule(seed=3, slowdowns=((4, 8, 1, 250.0),
                                               (6, 10, -1, 100.0)))
    assert not sched.active and not sched.egress_active
    assert sched.slow_us(2) == 0.0
    assert sched.slow_us(5, shard=1) == 250.0
    assert sched.slow_us(5, shard=0) == 0.0        # window targets shard 1
    assert sched.slow_us(7) == 250.0               # worst over all shards
    assert sched.slow_us(9) == 100.0
    keys = jnp.arange(32)
    for t in range(12):
        assert not np.asarray(sched.fetch_fail(t, keys)).any()
        assert not np.asarray(sched.egress_fail(t, keys)).any()


def test_egress_chaos_soak_invariants_and_determinism():
    """Mixed access/update/evacuate with BOTH fault directions armed:
    structural invariants hold at every step and the trajectory is a pure
    function of the seed (acceptance: same-seed chaos counters are
    bit-identical)."""
    sched = faults.Schedule(seed=9, fail_prob=0.15, egress_prob=0.25,
                            outages=((6, 10, -1),))

    def soak():
        cfg, data, s = mk(faults=sched)
        rng = np.random.RandomState(1)
        for i in range(24):
            ids = jnp.asarray(rng.randint(0, 96, size=16), jnp.int32)
            op = i % 3
            if op == 0:
                s, _ = batch_lib.access(cfg, s, ids)
            elif op == 1:
                rows = jnp.asarray(
                    rng.standard_normal((16, cfg.obj_dim)), jnp.float32)
                s = batch_lib.update(cfg, s, ids, rows)
            else:
                s = evacuate(cfg, s)
            check_invariants(cfg, s)
        return cfg, s

    cfg, sa = soak()
    _, sb = soak()
    assert_states_equal(sa, sb, "egress chaos soak replay")
    assert int(sa.stats.fetch_failures) > 0
    assert int(sa.stats.egress_failures) > 0, "egress schedule never fired"


def test_egress_faulted_update_writes_nothing():
    """No-partial-write, write direction: at a tick where every remote
    WRITE fails (fetches are fine), an update of remote objects under full
    frame pressure mutates NEITHER tier — the eviction writeback faults,
    so the fetch is dropped and the displaced slab write is masked too."""
    # device tick of the k-th batch op is k+1; ticks 1-3 fill the frames,
    # tick 4 is the faulted update, tick 5 the clean retry
    sched = faults.Schedule(seed=0, egress_prob=1.0, egress_window=(4, 5))
    cfg, data, s = mk(faults=sched)
    for start in (0, 16, 32):           # 6 pages -> all 6 frames occupied
        s, _ = batch_lib.access(cfg, s, jnp.arange(start, start + 16,
                                                   dtype=jnp.int32))
    ids = jnp.arange(48, 64, dtype=jnp.int32)        # two REMOTE pages
    resident = jnp.arange(0, 48, dtype=jnp.int32)
    before = peek(cfg, s, ids)
    before_local = peek(cfg, s, resident)
    before_vpage_of = np.asarray(s.vpage_of)
    new_rows = jnp.full((16, cfg.obj_dim), 123.0, jnp.float32)
    s = batch_lib.update(cfg, s, ids, new_rows)      # tick 4: egress dies
    check_invariants(cfg, s)
    assert int(s.stats.egress_failures) > 0, "egress gate never fired"
    # neither tier moved: no eviction landed, far values intact, and the
    # local tier still holds exactly the pre-fault bytes
    np.testing.assert_array_equal(np.asarray(s.vpage_of), before_vpage_of)
    np.testing.assert_array_equal(np.asarray(peek(cfg, s, ids)),
                                  np.asarray(before))
    np.testing.assert_array_equal(np.asarray(peek(cfg, s, resident)),
                                  np.asarray(before_local))
    # tick 5 is clean: the retry lands the write
    s = batch_lib.update(cfg, s, ids, new_rows)
    np.testing.assert_array_equal(np.asarray(peek(cfg, s, ids)),
                                  np.asarray(new_rows))


def test_egress_faulted_evacuate_moves_nothing():
    """A fully egress-faulted evacuation skips every victim atomically:
    no rows move, no page is freed — only ``egress_failures`` records the
    blocked compactions; the victims stay eligible for a later slice."""
    cfg0, data, s = mk(num_frames=8)
    cfg_f, _, _ = mk(num_frames=8,
                     faults=faults.Schedule(seed=2, egress_prob=1.0))
    rng = np.random.RandomState(2)
    # object-path churn fills log pages with mixed-heat objects; threshold
    # -1 makes every local page a victim (read-only churn keeps garbage
    # remote, same trick as the evacuation tests in test_system.py)
    for _ in range(20):
        s, _ = batch_lib.access(cfg0, s,
                                jnp.asarray(rng.choice(96, 12), jnp.int32))
    s_f = evacuate(cfg_f, s, garbage_threshold=-1.0, clear_access=False)
    s_0 = evacuate(cfg0, s, garbage_threshold=-1.0, clear_access=False)
    assert int(s_0.stats.evac_pages) > int(s.stats.evac_pages), \
        "fault-free twin found no victims — the gate was never exercised"
    assert int(s_f.stats.egress_failures) > 0
    assert int(s_f.stats.evac_pages) == int(s.stats.evac_pages)
    for f in s._fields:
        if f == "stats":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(s_f, f)), np.asarray(getattr(s, f)),
            err_msg=f"faulted evacuation mutated PlaneState.{f}")
    check_invariants(cfg_f, s_f)


def test_kv_append_egress_skips_atomically():
    """A faulted KV append mutates nothing on any shard: no slab row, no
    kmax/kmin summary, no frame write-through — and a clean-schedule twin
    proves the same call would have appended."""
    from repro.core import kvplane
    mkcfg = lambda fc: kvplane.KVPlaneConfig(
        kv_heads=1, head_dim=8, page_tokens=4, num_pages=8, num_frames=3,
        batch=1, sparse_topk=3, fetch_budget=2, dtype=jnp.float32,
        faults=fc)
    cfg_f = mkcfg(faults.Schedule(seed=4, egress_prob=1.0))
    cfg_0 = mkcfg(None)
    D = 2
    states = jax.vmap(lambda _: kvplane.init(cfg_0))(jnp.arange(D))
    kn = jnp.ones((1, 1, 8), jnp.float32)
    vn = jnp.ones((1, 1, 8), jnp.float32)
    lengths = jnp.asarray([0], jnp.int32)
    out_f = kvplane.append_sharded(cfg_f, states, kn, vn, lengths)
    out_0 = kvplane.append_sharded(cfg_0, states, kn, vn, lengths)
    for f in states._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(out_f, f)), np.asarray(getattr(states, f)),
            err_msg=f"faulted append mutated KVPlaneState.{f}")
    assert not np.array_equal(np.asarray(out_0.k_slab),
                              np.asarray(states.k_slab)), \
        "clean twin appended nothing — the test exercised no write"


# ---------------------------------------------------------------------------
# per-shard circuit breaker (DESIGN.md §6c)
# ---------------------------------------------------------------------------

def test_per_shard_degmask_healthy_shard_bit_identical():
    """The [S] degraded-mask program: with shard 0 degraded, every request
    OWNED by shard 1 — rows, served verdicts and shard 1's state slice —
    is bit-identical to the fault-free oracle (requests route by static
    ownership, so a tripped peer cannot perturb a healthy shard's plan).
    An all-False mask reproduces the plain program exactly (the engine
    dispatches every breaker state through this one compiled entry)."""
    base, _, _ = mk(num_objs=192, num_frames=12, num_vpages=80)
    scfg = shardplane.make_config(base, 2, 16, plane="hybrid")
    data = jnp.arange(192 * 4, dtype=jnp.float32).reshape(192, 4)
    fn_deg = shardplane.jitted_access_degmask(scfg, with_served=True)
    fn_pln = shardplane.jitted_access(scfg, with_served=True)
    s_a = s_b = s_c = shardplane.create(scfg, data)
    dmask = jnp.asarray([True, False])
    none = jnp.zeros((2,), bool)
    rng = np.random.RandomState(5)
    degraded_masked = False
    for t in range(8):
        ids = jnp.asarray(rng.randint(0, 192, size=(2, 16)), jnp.int32)
        s_a, r_a, v_a = fn_deg(s_a, ids, dmask)      # shard 0 tripped
        s_b, r_b, v_b = fn_pln(s_b, ids)             # fault-free oracle
        s_c, r_c, v_c = fn_deg(s_c, ids, none)       # all-healthy mask
        np.testing.assert_array_equal(np.asarray(r_c), np.asarray(r_b),
                                      err_msg=f"all-False mask t={t}")
        np.testing.assert_array_equal(np.asarray(v_c), np.asarray(v_b))
        own1 = np.asarray(ids // scfg.shard.num_objs) == 1
        np.testing.assert_array_equal(np.asarray(r_a)[own1],
                                      np.asarray(r_b)[own1],
                                      err_msg=f"healthy-shard rows t={t}")
        np.testing.assert_array_equal(np.asarray(v_a)[own1],
                                      np.asarray(v_b)[own1])
        degraded_masked |= bool((~np.asarray(v_a)[~own1]).any())
    assert degraded_masked, "degraded shard never masked a request"
    assert_states_equal(state_lib.shard_slice(s_c, 0),
                        state_lib.shard_slice(s_b, 0), "all-False shard 0")
    assert_states_equal(state_lib.shard_slice(s_a, 1),
                        state_lib.shard_slice(s_b, 1),
                        "healthy shard under a tripped peer")


@needs8
@pytest.mark.parametrize("shards", [2, 4])
def test_shard_map_degmask_matches_oracle(shards):
    """The shard_map build of the degraded-mask program is bit-identical
    to the vmap oracle — rows, served verdicts and full state — for a
    mask that trips shard 0 and for the all-healthy mask."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import mesh as mesh_lib

    sched = faults.Schedule(seed=23, fail_prob=0.2, egress_prob=0.2)
    base, _, _ = mk(num_objs=96 * shards, num_frames=6 * shards,
                    num_vpages=40 * shards, faults=sched)
    scfg = shardplane.make_config(base, shards, 16, plane="hybrid")
    data = jnp.arange(base.num_objs * base.obj_dim, dtype=jnp.float32
                      ).reshape(base.num_objs, base.obj_dim)
    s_emu = shardplane.create(scfg, data)
    mesh = mesh_lib.make_far_mesh(shards)
    s_dev = jax.device_put(s_emu, jax.tree.map(
        lambda _: NamedSharding(mesh, P("far")), s_emu))
    a_emu = shardplane.jitted_access_degmask(scfg, with_served=True)
    a_dev = shardplane.jitted_access_degmask(scfg, mesh=mesh,
                                             with_served=True)
    dmask = jnp.zeros((shards,), bool).at[0].set(True)
    rng = np.random.RandomState(8)
    for t in range(6):
        ids = jnp.asarray(rng.randint(0, base.num_objs, size=(shards, 16)),
                          jnp.int32)
        deg = dmask if t % 2 else jnp.zeros((shards,), bool)
        s_emu, r_emu, v_emu = a_emu(s_emu, ids, deg)
        s_dev, r_dev, v_dev = a_dev(s_dev, ids, deg)
        np.testing.assert_array_equal(np.asarray(r_emu), np.asarray(r_dev),
                                      err_msg=f"rows t={t}")
        np.testing.assert_array_equal(np.asarray(v_emu), np.asarray(v_dev),
                                      err_msg=f"served t={t}")
    assert_states_equal(s_emu, s_dev, f"shard_map degmask S={shards}")


def test_engine_per_shard_breaker_isolates_faulty_shard():
    """Single-shard outage, shards=2: ONLY shard 0's breaker trips (shard
    1 never sees failure evidence), the healthy shard keeps serving at
    >=0.9x its fault-free goodput, both breakers close after recovery,
    and same-seed runs produce identical chaos counters.  The legacy
    ``breaker_scope="global"`` run drags the healthy shard down with the
    faulty one."""
    sched = faults.Schedule(seed=7, outages=((6, 46, 0),))
    kw = dict(max_retries=1, breaker_threshold=0.5, breaker_probe_every=4)

    def drive(scope, faulted=True):
        eng, _, _ = mk_engine_pair(
            shards=2, faults_sched=sched if faulted else faults.NULL,
            robust_kw=dict(breaker_scope=scope, **kw))
        open_seen = np.zeros((2,), bool)
        for s in range(70):
            ids = np.random.RandomState(s).randint(
                0, 256, size=16).astype(np.int32)
            eng.submit(ids)
            eng.drain()
            open_seen |= eng.breaker_open_shards
        eng.flush_retries()
        return eng, open_seen

    eng, open_seen = drive("shard")
    assert open_seen[0], "faulty shard's breaker never opened"
    assert not open_seen[1], "outage leaked into the healthy shard's breaker"
    assert not eng.breaker_open, "breaker failed to close after recovery"
    assert eng.counters["breaker_trips"] >= 1
    assert eng.counters["degraded_ticks"] > 0
    # healthy-shard goodput: within 10% of the fault-free twin
    eng_ok, _ = drive("shard", faulted=False)
    assert (eng.served_per_shard[1]
            >= 0.9 * eng_ok.served_per_shard[1]), (eng.served_per_shard,
                                                   eng_ok.served_per_shard)
    # same seed, same trajectory -> identical chaos accounting
    eng2, _ = drive("shard")
    assert eng.counters == eng2.counters
    np.testing.assert_array_equal(eng.served_per_shard,
                                  eng2.served_per_shard)
    # the global breaker degrades BOTH shards: healthy-shard serves drop
    eng_g, open_g = drive("global")
    assert open_g.all(), "global scope must trip every shard together"
    assert eng_g.served_per_shard[1] < eng.served_per_shard[1], \
        "global breaker did not cost the healthy shard anything"
