"""Per-architecture smoke tests: REDUCED same-family configs run one
forward/train step on CPU — output shapes + finite values.  (Full configs
are exercised only via the dry-run.)"""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import api, lm
from repro.models.lm import pad_vocab
from repro.optim import get_optimizer


@pytest.fixture(scope="module")
def rngs():
    return jax.random.PRNGKey(0), jax.random.PRNGKey(1)


def _batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, 8, cfg.d_model),
                                            cfg.dtype)
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend_seq, cfg.frontend_dim), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", cfgs.ARCHS)
def test_smoke_forward_and_train_step(arch, rngs):
    cfg = cfgs.get_smoke(arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, remat=False)
    params = api.init_params(cfg, rngs[0])
    batch = _batch(cfg)

    # forward: logits shape + finite
    if cfg.family != "encdec":
        logits, _ = lm.forward(cfg, params, batch["tokens"],
                               batch.get("patches"))
        assert logits.shape == (2, 16, pad_vocab(cfg.vocab))
        assert bool(jnp.isfinite(logits).all())

    # one full train step (loss + grad + optimizer update)
    opt = get_optimizer("adamw", lr=lambda s: 1e-3)
    step_fn = jax.jit(api.make_train_step(cfg, opt))
    opt_state = opt.init(params)
    new_p, new_o, step, loss, gnorm = step_fn(
        params, opt_state, jnp.zeros((), jnp.int32), batch)
    assert bool(jnp.isfinite(loss)), arch
    assert bool(jnp.isfinite(gnorm)), arch
    assert float(loss) > 0
    # params actually changed
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_p)
    assert any(jax.tree.leaves(changed)), arch


@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b", "xlstm-350m",
                                  "zamba2-1.2b", "kimi-k2-1t-a32b"])
def test_smoke_decode(arch, rngs):
    cfg = cfgs.get_smoke(arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, remat=False)
    shape = cfgs.ShapeConfig("smoke_decode", 64, 2, "decode")
    params = api.init_params(cfg, rngs[0])
    state = api.init_decode_state(cfg, shape)
    step = jax.jit(api.decode_step(cfg, shape))
    for t in range(3):
        tok = jax.random.randint(jax.random.PRNGKey(t), (2,), 0, cfg.vocab)
        state, logits = step(params, state, tok)
        assert logits.shape == (2, pad_vocab(cfg.vocab))
        assert bool(jnp.isfinite(logits).all()), arch
    assert int(state.lengths[0]) == 3


def test_exact_assigned_configs():
    """The full configs carry exactly the assigned hyperparameters."""
    expect = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "seamless-m4t-medium": (24, 1024, 16, 16, 4096, 256206),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }
    for arch, (L, d, H, KV, ff, V) in expect.items():
        c = cfgs.get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, H, KV, ff, V), arch
    assert cfgs.get_config("mixtral-8x7b").moe_experts == 8
    assert cfgs.get_config("kimi-k2-1t-a32b").moe_experts == 384
    assert cfgs.get_config("kimi-k2-1t-a32b").moe_topk == 8
    assert cfgs.get_config("zamba2-1.2b").ssm_state == 64
    assert cfgs.get_config("seamless-m4t-medium").enc_layers == 12
