"""Dry-run machinery smoke test on 8 fake devices (subprocess so the main
test process keeps its single-device view)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax, jax.numpy as jnp
from repro import configs as cfgs
from repro.launch import mesh as mesh_lib
from repro.models import api
from repro.optim import get_optimizer

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = cfgs.get_smoke("llama3-8b")
cfg = dataclasses.replace(cfg, n_kv_heads=2)
shape = cfgs.ShapeConfig("smoke", 64, 8, "train")
opt = get_optimizer("adamw")
fn = api.make_train_step(cfg, opt)
params_struct = api.param_shapes(cfg)
opt_struct = jax.eval_shape(opt.init, params_struct)
bs = api.batch_specs(cfg, shape)
with mesh:
    jitted = jax.jit(fn, in_shardings=(
        mesh_lib.sharding_tree(mesh, api.param_pspecs(cfg)),
        mesh_lib.sharding_tree(mesh, api.opt_state_pspecs(cfg, "adamw")),
        mesh_lib.sharding_tree(mesh, None),
        mesh_lib.sharding_tree(mesh, {k: v[1] for k, v in bs.items()})))
    lowered = jitted.lower(params_struct, opt_struct,
                           jax.ShapeDtypeStruct((), jnp.int32),
                           {k: v[0] for k, v in bs.items()})
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):   # older jax returns one dict per device
    ca = ca[0]
from repro.analysis import hlo
coll = hlo.collective_summary(compiled.as_text())
print(json.dumps({"flops": ca.get("flops", 0),
                  "ar": coll["all-reduce"]["count"]}))
"""


def test_dryrun_smoke_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, cwd=".",
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["ar"] > 0        # data-parallel gradient sync exists
