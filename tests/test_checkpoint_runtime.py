"""Fault tolerance: checkpoint/restore, elastic resharding, async saves,
failure-recovery through the orchestrator, straggler accounting."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.runtime.orchestrator import (FailureInjector, Orchestrator,
                                        OrchestratorConfig)


def tree_eq(a, b):
    return all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool(np.allclose(np.asarray(x), np.asarray(y))), a, b)))


def test_save_restore_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,)), "step": jnp.asarray(7)}}
    ckpt.save(str(tmp_path), 3, tree, extra={"next_step": 3})
    assert ckpt.latest(str(tmp_path)) == 3
    got, extra = ckpt.restore(str(tmp_path), 3, tree)
    assert tree_eq(tree, got)
    assert extra["next_step"] == 3


def test_atomic_publish_never_partial(tmp_path):
    tree = {"w": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 1, tree)
    # a stale tmp dir from a crashed writer must not count as a checkpoint
    os.makedirs(tmp_path / "step_2.tmp")
    assert ckpt.latest(str(tmp_path)) == 1


def test_elastic_restore_new_mesh(tmp_path):
    """Checkpoint written untouched by mesh, restored onto a (1,1) mesh with
    explicit shardings (the elastic-scaling path)."""
    from repro.launch import mesh as mesh_lib
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 5, tree)
    mesh = mesh_lib.make_host_mesh(1, 1)
    got, _ = ckpt.restore(str(tmp_path), 5, tree, mesh=mesh,
                          spec_tree={"w": ("dp", "tp")})
    assert tree_eq(tree, got)
    assert got["w"].sharding.mesh.shape == {"data": 1, "model": 1}


def test_prune(tmp_path):
    tree = {"w": jnp.ones(2)}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, tree)
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest(str(tmp_path)) == 5
    assert sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)) == [4, 5]


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((64, 64))}
    saver.save(10, tree)
    saver.wait()
    assert ckpt.latest(str(tmp_path)) == 10


def _toy_problem():
    """A trainable state that descends monotonically: state = (params,
    step_counter) — two leaves so restore coverage includes both."""
    def train_step(state, batch):
        w, n = state
        grad = 2 * (w - batch)          # d/dw (w - b)^2
        w = w - 0.1 * grad
        return (w, n + 1), {"loss": jnp.mean((w - batch) ** 2)}
    return jax.jit(train_step)


def test_orchestrator_failure_recovery(tmp_path):
    step_fn = _toy_problem()
    target = jnp.full((4,), 3.0)
    batch_fn = lambda step: target
    inj = FailureInjector(fail_at_steps=[7, 13])
    orch = Orchestrator(
        OrchestratorConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
        step_fn, batch_fn, injector=inj)
    init = (jnp.zeros((4,)), jnp.zeros((4,)))
    state = orch.run(init, num_steps=40)
    assert orch.metrics["restarts"] == 2
    assert inj.failures == 2
    # training converged despite two failures
    assert float(jnp.abs(state[0] - 3.0).max()) < 0.1


def test_orchestrator_resume_determinism(tmp_path):
    """Run A: 20 uninterrupted steps.  Run B: killed at 9, resumed.
    Checkpointed state at the end must match exactly (step-indexed data)."""
    step_fn = _toy_problem()
    batch_fn = lambda step: jnp.full((4,), float(step % 5))

    orch_a = Orchestrator(OrchestratorConfig(ckpt_dir=str(tmp_path / "a"),
                                             ckpt_every=5),
                          step_fn, batch_fn)
    sa = orch_a.run((jnp.zeros(4), jnp.zeros(4)), 20)

    inj = FailureInjector(fail_at_steps=[9])
    orch_b = Orchestrator(OrchestratorConfig(ckpt_dir=str(tmp_path / "b"),
                                             ckpt_every=5),
                          step_fn, batch_fn, injector=inj)
    sb = orch_b.run((jnp.zeros(4), jnp.zeros(4)), 20)
    np.testing.assert_allclose(np.asarray(sa[0]), np.asarray(sb[0]),
                               rtol=1e-6)


def test_straggler_accounting(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 8:
            time.sleep(0.25)            # one straggler step
        return state, {}

    orch = Orchestrator(OrchestratorConfig(ckpt_dir=str(tmp_path),
                                           ckpt_every=100,
                                           straggler_factor=5.0),
                        step_fn, lambda s: jnp.zeros(1))
    orch.run((jnp.zeros(1),), num_steps=12)
    assert orch.metrics["stragglers"] >= 1
