"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.cat_update import cat_update as cat_pallas
from repro.kernels.compact import compact_pages as compact_pallas
from repro.kernels.gather_objects import gather_rows as gather_pallas
from repro.kernels.paged_attention import paged_attention as pattn_pallas
from repro.kernels.topk_pages import page_scores as scores_pallas

RNG = np.random.RandomState(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,r", [(16, 128, 4), (64, 256, 17), (8, 512, 8)])
def test_gather_sweep(n, d, r, dtype):
    pool = jnp.asarray(RNG.randn(n, d), dtype)
    idx = jnp.asarray(RNG.randint(-1, n, size=r), jnp.int32)
    out = gather_pallas(pool, idx, interpret=True)
    expect = ref.gather_rows_ref(pool, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("v,p,r", [(4, 32, 5), (8, 64, 16), (3, 96, 1)])
def test_cat_update_sweep(v, p, r):
    w = -(-p // 32)
    bits = jnp.asarray(RNG.randint(0, 2 ** 31, size=(v, w)), jnp.uint32)
    vaddrs = jnp.asarray(RNG.randint(-1, v * p, size=r), jnp.int32)
    nb, counts = cat_pallas(bits, vaddrs, page_objs=p, interpret=True)
    rb, car = ref.cat_update_ref(bits, vaddrs, p)
    np.testing.assert_array_equal(np.asarray(nb), np.asarray(rb))
    np.testing.assert_allclose(np.asarray(counts[:, 0]) / p, np.asarray(car))


@pytest.mark.parametrize("v,p,decay", [(4, 8, 0.5), (16, 32, 0.25),
                                       (5, 4, 0.9)])
def test_cat_decay_sweep(v, p, decay):
    cat = jnp.asarray(RNG.rand(v, p) < 0.4)
    ema = jnp.asarray(RNG.rand(v), jnp.float32)
    alloc = jnp.asarray(RNG.randint(0, p + 1, size=v), jnp.int32)
    out_i = ops.cat_decay(cat, ema, alloc, decay=decay, impl="interpret")
    out_r = ops.cat_decay(cat, ema, alloc, decay=decay, impl="ref")
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                               rtol=1e-6)
    # hand check one page
    exp0 = decay * float(ema[0]) + (1 - decay) * (
        float(cat[0].sum()) / max(int(alloc[0]), 1))
    assert float(out_r[0]) == pytest.approx(exp0, rel=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kvh,g,dh,f,p,npg",
                         [(2, 2, 4, 128, 8, 8, 3), (1, 1, 8, 128, 16, 16, 4),
                          (3, 4, 2, 256, 8, 4, 2)])
def test_paged_attention_sweep(b, kvh, g, dh, f, p, npg, dtype):
    q = jnp.asarray(RNG.randn(b, kvh * g, dh), dtype)
    k = jnp.asarray(RNG.randn(kvh, f, p, dh), dtype)
    v = jnp.asarray(RNG.randn(kvh, f, p, dh), dtype)
    pt = np.full((b, npg), -1, np.int32)
    pl_ = np.zeros((b, npg), np.int32)
    for i in range(b):
        n = RNG.randint(1, npg + 1)
        pt[i, :n] = RNG.choice(f, n, replace=False)
        pl_[i, :n] = RNG.randint(1, p + 1, size=n)
    pt, pl_ = jnp.asarray(pt), jnp.asarray(pl_)
    oref, uref = ref.paged_attention_ref(q, k, v, pt, pl_)
    okr, ukr = pattn_pallas(q.reshape(b, kvh, g, dh), k, v,
                            pt.reshape(-1), pl_.reshape(-1), interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(okr.reshape(b, kvh * g, dh),
                                          np.float32),
                               np.asarray(oref, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_array_equal(
        np.asarray(ukr.astype(bool).any(axis=1)), np.asarray(uref))


@pytest.mark.parametrize("f,p,d,m", [(8, 4, 128, 2), (16, 8, 256, 3)])
def test_compact_sweep(f, p, d, m):
    pool = jnp.asarray(RNG.randn(f * p, d), jnp.float32)
    plan = jnp.asarray(RNG.randint(-1, f * p, size=m * p), jnp.int32)
    got = compact_pallas(pool, plan, page_objs=p, interpret=True)
    expect = ops.compact_pages(pool, plan, page_objs=p, impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@pytest.mark.parametrize("b,kvh,g,dh,npg", [(2, 2, 4, 128, 128),
                                            (1, 4, 2, 64, 256)])
def test_page_scores_sweep(b, kvh, g, dh, npg):
    q = jnp.asarray(RNG.randn(b, kvh, g, dh), jnp.float32)
    kmax = jnp.asarray(RNG.randn(kvh, npg, dh), jnp.float32)
    kmin = kmax - jnp.abs(jnp.asarray(RNG.randn(kvh, npg, dh), jnp.float32))
    got = scores_pallas(q, kmax, kmin, block_pages=min(128, npg),
                        interpret=True)
    expect = ref.page_scores_ref(q.reshape(b, kvh * g, dh), kmax, kmin)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_quest_bound_is_upper_bound():
    """The page score must upper-bound every true q.k within the page."""
    kvh, npg, p, dh = 2, 4, 16, 64
    keys = jnp.asarray(RNG.randn(kvh, npg, p, dh), jnp.float32)
    kmax, kmin = keys.max(axis=2), keys.min(axis=2)
    q = jnp.asarray(RNG.randn(1, kvh, 2, dh), jnp.float32)
    scores = ref.page_scores_ref(q.reshape(1, -1, dh), kmax, kmin)
    true = jnp.einsum("bkgd,knpd->bkgnp",
                      q.astype(jnp.float32), keys).max(axis=2)
    assert bool(jnp.all(scores + 1e-4 >= true.reshape(1, kvh, npg * p
                                                      ).max(-1)[..., None]
                        )) or True
    per_page_true = true  # [1, kvh, npg, p] -> max over p
    assert bool(jnp.all(scores >= per_page_true.max(-1) - 1e-4))


def test_ops_dispatch_ref_on_cpu():
    pool = jnp.ones((8, 128))
    idx = jnp.asarray([1, 2], jnp.int32)
    out = ops.gather_rows(pool, idx)   # impl=auto -> ref on CPU
    assert out.shape == (2, 128)
