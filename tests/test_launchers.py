"""End-to-end launcher drills (subprocess): training with an injected node
failure recovers and finishes; KV serving reports sane latency."""
import os
import subprocess
import sys

import pytest


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-m"] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_train_launcher_failure_drill(tmp_path):
    out = _run(["repro.launch.train", "--arch", "llama3-8b", "--smoke",
                "--steps", "24", "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "8",
                "--fail-at", "11", "--log-every", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "restarts=1" in out.stdout, out.stdout
    assert "loss" in out.stdout


def test_serve_launcher_kv(tmp_path):
    out = _run(["repro.launch.serve", "--mode", "kv", "--plane", "hybrid",
                "--workload", "mcd_cl", "--steps", "20", "--objects", "512"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "paging fraction" in out.stdout
