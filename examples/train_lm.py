"""End-to-end training driver: a GPT-style LM trained with the full
substrate stack (deterministic data, AdamW, async checkpointing, failure
injection + automatic recovery, straggler accounting).

Default: ~10M params x 100 steps (a few minutes on CPU).
--full:   ~100M params x 300 steps (the deliverable-scale run; slow on CPU,
          sized for a single accelerator).

  PYTHONPATH=src python examples/train_lm.py [--full] [--drill]
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # re-build argv for the launcher
parser = argparse.ArgumentParser()
parser.add_argument("--full", action="store_true")
parser.add_argument("--drill", action="store_true",
                    help="inject a node failure mid-run (recovery drill)")
args, _ = parser.parse_known_args()

from repro.launch import train as train_launcher

if args.full:
    # ~100M params: 12L x d=768 (GPT-2 small scale)
    sys.argv += ["--arch", "llama3-8b", "--smoke", "--steps", "300",
                 "--batch", "8", "--seq", "512", "--ckpt-dir",
                 "/tmp/repro_train_full"]
    import dataclasses, jax.numpy as jnp
    from repro import configs
    cfg = configs.get_smoke("llama3-8b").scaled(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
        vocab=32000)
    configs._module("llama3-8b").SMOKE = cfg   # 100M-param variant
else:
    sys.argv += ["--arch", "llama3-8b", "--smoke", "--steps", "100",
                 "--batch", "8", "--seq", "256", "--ckpt-dir",
                 "/tmp/repro_train_demo"]
    import dataclasses
    from repro import configs
    cfg = configs.get_smoke("llama3-8b").scaled(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
        vocab=8192)
    configs._module("llama3-8b").SMOKE = cfg   # ~10M-param variant

if args.drill:
    sys.argv += ["--fail-at", "37"]

train_launcher.main()
