"""Atlas sparse long-context decode: the KV cache lives in the hybrid
plane; each step scores far-resident page summaries (offload-space
compute), fetches the top-k pages through the PSF-selected path, and
attends over the local pool only.

  PYTHONPATH=src python examples/long_context_decode.py
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvplane

D_SHARDS, KVH, G, Dh, P, NPS = 4, 2, 2, 64, 16, 32   # 4*32*16 = 2048 tokens
cfg = kvplane.KVPlaneConfig(kv_heads=KVH, head_dim=Dh, page_tokens=P,
                            num_pages=NPS, num_frames=8, batch=1,
                            sparse_topk=6, fetch_budget=2, dtype=jnp.float32)
states = jax.vmap(lambda _: kvplane.init(cfg))(jnp.arange(D_SHARDS))

rng = np.random.default_rng(0)
lengths = jnp.asarray([0], jnp.int32)
append = jax.jit(partial(kvplane.append_sharded, cfg))
print("prefilling 2048 tokens into the far tier...")
for t in range(D_SHARDS * NPS * P):
    kv = rng.standard_normal((2, 1, KVH, Dh)).astype(np.float32) * 0.3
    states = append(states, jnp.asarray(kv[0]), jnp.asarray(kv[1]), lengths)
    lengths = lengths + 1

decode = jax.jit(partial(kvplane.sharded_sparse_decode, cfg))
for step in range(12):
    q = jnp.asarray(rng.standard_normal((1, KVH * G, Dh)), jnp.float32)
    out, states = decode(states, q, lengths)
    resident = int((states.page_table >= 0).sum())
    runtime_pages = int((~states.psf).sum())
    print(f"step {step:2d}: resident pages {resident:3d}/128  "
          f"runtime-path pages {runtime_pages:3d}  "
          f"hot-hint rows {int(states.hot_hint.sum()):4d}  |out|="
          f"{float(jnp.linalg.norm(out)):.3f}")
print("\nPages whose attention concentrated on few rows flip to the "
      "runtime path and re-fetch packed;\nflat pages stay on paging — the "
      "hybrid data plane at decode time.")
