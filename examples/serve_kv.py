"""Far-memory KV serving example: latency distribution per data plane on
the Meta-CacheLib-like workload (skew + churn), 25% local memory.

  PYTHONPATH=src python examples/serve_kv.py
"""
import jax.numpy as jnp

from repro.core.layout import PlaneConfig
from repro.data import kvworkload
from repro.serving.engine import Engine, EngineConfig

N = 4096
pcfg = PlaneConfig(num_objs=N, obj_dim=32, page_objs=8,
                   num_frames=int((N // 8) * 0.25), num_vpages=3 * (N // 8),
                   readahead=2)
data = jnp.arange(N * 32, dtype=jnp.float32).reshape(N, 32)

print(f"{'plane':<9}{'p50 us':>9}{'p90 us':>9}{'p99 us':>9}{'paging%':>9}")
for plane in ["hybrid", "paging", "object"]:
    eng = Engine(EngineConfig(plane=plane, batch=64), pcfg, data)
    rep = eng.run(kvworkload.zipf_churn(N, 64, steps=100, seed=0))
    lat = rep["latency"]
    print(f"{plane:<9}{lat['p50_us']:>9.0f}{lat['p90_us']:>9.0f}"
          f"{lat['p99_us']:>9.0f}{rep['paging_fraction']:>8.0%}")
