"""Quickstart: the Atlas hybrid data plane in ~30 lines.

Creates a far-memory-resident object store, drives it with a mixed access
pattern, and shows the plane adapting its per-page data path (PSF) —
paging for the sequential phase, object fetching for the random phase.

  PYTHONPATH=src python examples/quickstart.py
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PlaneConfig, access, create, paging_fraction

# 4096 objects of 32 floats; only 25% fit in local memory
cfg = PlaneConfig(num_objs=4096, obj_dim=32, page_objs=8,
                  num_frames=int(512 * 0.25), num_vpages=1536, readahead=2)
data = jnp.arange(4096 * 32, dtype=jnp.float32).reshape(4096, 32)
state = create(cfg, data)
fetch = jax.jit(partial(access, cfg))

rng = np.random.default_rng(0)
print(f"{'phase':<12}{'hits':>7}{'page_ins':>9}{'obj_ins':>8}{'paging%':>9}")
for phase, gen in [
    ("sequential", lambda i: (np.arange(64) + 64 * i) % 4096),
    ("random", lambda i: rng.integers(0, 4096, 64)),
    ("sequential", lambda i: (np.arange(64) + 64 * i) % 4096),
]:
    before = jax.device_get(state.stats)
    for i in range(40):
        state, rows = fetch(state, jnp.asarray(gen(i), jnp.int32))
    after = jax.device_get(state.stats)
    print(f"{phase:<12}"
          f"{int(after.hits - before.hits):>7}"
          f"{int(after.page_ins - before.page_ins):>9}"
          f"{int(after.obj_ins - before.obj_ins):>8}"
          f"{float(paging_fraction(cfg, state)):>8.0%}")

print("\nThe plane chose paging for sequential phases and object fetching "
      "for the random phase\n(PSF flips happen at page-out, from each "
      "page's measured card access rate).")
