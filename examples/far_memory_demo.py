"""The paper's Figure-4 story in one table: hybrid vs paging-only
(Fastswap-like) vs object-only (AIFM-like) far-memory traffic across access
patterns, at 25% local memory.

  PYTHONPATH=src python examples/far_memory_demo.py
"""
import sys
sys.path.insert(0, ".")

from benchmarks.common import plane_config, run_workload, traffic_bytes
from repro.data import kvworkload

N = 2048
print(f"{'workload':<10}{'plane':<9}{'traffic KB':>11}{'LRU scans':>11}"
      f"{'paging%':>9}")
for wl in ["df_scan", "mcd_u", "mcd_cl", "ws"]:
    for plane in ["hybrid", "paging", "object"]:
        cfg = plane_config(0.25)
        us, stats, _ = run_workload(
            plane, cfg, kvworkload.WORKLOADS[wl](N, 64, 50, seed=1),
            evac_every=16)
        print(f"{wl:<10}{plane:<9}"
              f"{traffic_bytes(cfg, stats) / 1024:>11.1f}"
              f"{stats['lru_scans']:>11,}"
              f"{stats['paging_fraction']:>8.0%}")
    print()
print("hybrid ~ paging on scans, ~ object on random access, and never "
      "pays the object plane's\nLRU scan bill — the paper's headline "
      "tradeoff.")
