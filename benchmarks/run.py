"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` runs reduced
sweeps (used by CI); the full run reproduces every figure's data.
``--json PATH`` additionally writes all rows (plus total wall time per
figure) to a JSON file — CI uploads these as ``BENCH_*.json`` artifacts.
``--compare BASELINE.json`` diffs the current run against a committed
baseline (per-cell us_per_call ratios, printed and written to
``BENCH_compare.json``) so every run is anchored to the repo's perf
trajectory instead of an empty void.
"""
import argparse
import json
import sys
import time


def compare_records(current: dict, baseline: dict) -> list[dict]:
    """Per-cell ratio of current vs baseline us_per_call (matched by row
    name across all figures; cells present on only one side are skipped)."""
    def rows_by_name(rec):
        out = {}
        for fig in rec.get("figures", {}).values():
            for r in fig.get("rows", []):
                out[r["name"]] = r["us_per_call"]
        return out

    cur, base = rows_by_name(current), rows_by_name(baseline)
    diffs = []
    for name in sorted(cur.keys() & base.keys()):
        b = base[name]
        diffs.append({"name": name, "us_per_call": cur[name],
                      "baseline_us": b,
                      "ratio": round(cur[name] / b, 3) if b else None})
    return diffs


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default="",
                   help="comma-separated figure names (fig4,fig56,...)")
    p.add_argument("--json", default="",
                   help="write results to this JSON file (CI artifact)")
    p.add_argument("--compare", default="",
                   help="baseline JSON (e.g. BENCH_baseline.json) to diff "
                        "against; ratios go to stdout + BENCH_compare.json")
    args = p.parse_args()

    from benchmarks import (fig1c_eviction, fig4_throughput, fig56_latency,
                            fig7_psf, fig9_overhead, fig10_car,
                            fig11_hotness, fig_faults, fig_prefetch,
                            fig_shard, kvdecode, roofline)

    figures = {
        "fig1c": fig1c_eviction.run,
        "fig4": fig4_throughput.run,
        "fig56": fig56_latency.run,
        "fig7": fig7_psf.run,
        "fig9": fig9_overhead.run,
        "fig10": fig10_car.run,
        "fig11": fig11_hotness.run,
        "fig_faults": fig_faults.run,
        "fig_prefetch": fig_prefetch.run,
        "fig_shard": fig_shard.run,
        "kvdecode": kvdecode.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    record = {"quick": args.quick, "figures": {}}
    print("name,us_per_call,derived")
    for name, fn in figures.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", file=sys.stderr)
            raise
        wall = time.time() - t0
        record["figures"][name] = {
            "wall_s": round(wall, 2),
            "rows": [{"name": r[0], "us_per_call": r[1], "derived": r[2]}
                     for r in (rows or [])],
        }
        print(f"# {name} done in {wall:.1f}s", file=sys.stderr)

    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        diffs = compare_records(record, baseline)
        print("compare_name,us_per_call,baseline_us,ratio")
        for d in diffs:
            print(f"{d['name']},{d['us_per_call']:.1f},"
                  f"{d['baseline_us']:.1f},{d['ratio']}")
        with open("BENCH_compare.json", "w") as f:
            json.dump({"baseline": args.compare, "cells": diffs}, f, indent=1)
        print("# wrote BENCH_compare.json", file=sys.stderr)
        record["compare"] = diffs

    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
