"""Serve-path decode microbenchmarks: kvplane sparse decode + expert fetch.

Times one decode step of ``kvplane.attend_sparse`` at a ``long_500k``-shaped
geometry (page_tokens=64, frames=96, topk/budget from ``models.api``'s
sparse config at 8 shards, B=1) plus a multi-sequence cell (B=8 sequences
sharing the frame pool), and one ``expertplane.ensure_resident`` fetch step
at a kimi-shaped hot-slot geometry.  Head count / dims are scaled down so
the slab fits a CPU runner; the fetch-plan work being measured (top-k
selection, eviction, page-in, hot-row packing) has the production shape.

All cells enter through the state-donating serve entry points
(``jitted_attend_sparse`` / ``jitted_ensure_resident``) — the form the
serving loop actually runs; the pre-PR scalar path had no such entry and
paid a full slab copy per step on top of its serialized fetch loop.
Each cell reports the batched executor and the scalar ``mode="reference"``
oracle (the seed-era access path replaying the identical plan).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expertplane as ep
from repro.core import kvplane


def _kv_cfg(batch: int, num_pages: int) -> kvplane.KVPlaneConfig:
    # long_500k @ 8 shards: NP = ceil(500_000 / (64 * 8)) = 977, B = 1
    return kvplane.KVPlaneConfig(
        kv_heads=2, head_dim=64, page_tokens=64, num_pages=num_pages,
        num_frames=96, batch=batch, sparse_topk=8, fetch_budget=4,
        dtype=jnp.float32)


def _prefill_kv(cfg, seed=0):
    """Build a fully-written far tier directly (python-loop prefill of ~1k
    pages would dominate the benchmark)."""
    rng = np.random.RandomState(seed)
    s = kvplane.init(cfg)
    KVH, P, Dh = cfg.kv_heads, cfg.page_tokens, cfg.head_dim
    pages = cfg.batch * cfg.num_pages
    k = rng.randn(KVH, pages, P, Dh).astype(np.float32)
    v = rng.randn(KVH, pages, P, Dh).astype(np.float32)
    return s._replace(
        k_slab=jnp.asarray(k), v_slab=jnp.asarray(v),
        kmax=jnp.asarray(k.max(axis=2)), kmin=jnp.asarray(k.min(axis=2)))


def _kv_cell(name, cfg, iters):
    rows = []
    rng = np.random.RandomState(1)
    lengths = jnp.full((cfg.batch,), cfg.num_pages * cfg.page_tokens,
                       jnp.int32)
    qs = [jnp.asarray(rng.randn(cfg.batch, 4, cfg.head_dim), jnp.float32)
          for _ in range(8)]
    for mode in ["batch", "reference"]:
        step = kvplane.jitted_attend_sparse(cfg, mode)
        st = _prefill_kv(cfg)
        for q in qs:                          # compile + settle the churn
            out, st = step(st, q, lengths)
        jax.block_until_ready(out)
        t0 = time.time()
        n = 0
        for _ in range(iters):
            for q in qs:                      # churn the top-k selection
                out, st = step(st, q, lengths)
                n += 1
        jax.block_until_ready(out)
        ms = (time.time() - t0) / n * 1e3
        rows.append((f"{name}/{mode}", ms * 1e3, f"ms_per_step={ms:.3f}"))
    return rows


def run(quick: bool = False):
    iters = 2 if quick else 5
    rows = []
    rows += _kv_cell("kvdecode/attend_sparse_long500k", _kv_cfg(1, 977),
                     iters)
    rows += _kv_cell("kvdecode/attend_sparse_multiseq8", _kv_cfg(8, 128),
                     iters)

    # --- expert fetch (kimi-shaped slots, scaled dims) ---------------------
    rng = np.random.RandomState(2)
    ecfg = ep.ExpertPlaneConfig(n_experts=128, d_model=256, d_ff=512,
                                hot_slots=32, topk=8, fetch_budget=8,
                                dtype=jnp.float32)
    wi = jnp.asarray(rng.randn(128, 256, 512) * 0.05, jnp.float32)
    wg = jnp.asarray(rng.randn(128, 256, 512) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.randn(128, 512, 256) * 0.05, jnp.float32)
    masks = [jnp.zeros((128,), bool).at[
        jnp.asarray(rng.choice(128, 16, replace=False))].set(True)
        for _ in range(8)]
    for mode in ["batch", "reference"]:
        fetch = ep.jitted_ensure_resident(ecfg, mode)
        es = ep.init(ecfg)
        for m in masks:                       # compile + settle the churn
            es = fetch(es._replace(step=es.step + 1), m, wi, wg, wo)
        jax.block_until_ready(es.clock)
        t0 = time.time()
        n = 0
        for _ in range(iters):
            for m in masks:                   # churn the hot set
                es = fetch(es._replace(step=es.step + 1), m, wi, wg, wo)
                n += 1
        jax.block_until_ready(es.clock)
        ms = (time.time() - t0) / n * 1e3
        rows.append((f"kvdecode/expert_fetch/{mode}", ms * 1e3,
                     f"ms_per_step={ms:.3f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
