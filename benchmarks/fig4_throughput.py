"""Paper Fig. 4: throughput of Atlas (hybrid) vs Fastswap (paging) vs AIFM
(object) across workloads x local-memory ratios.

Reports per cell: wall us/batch and modeled far-memory traffic (bytes) —
the qualitative claims under reproduction:
  * random/skewed workloads: hybrid & object beat paging (I/O amplification)
  * sequential workloads: hybrid & paging beat object
  * hybrid >= max(both) within tolerance everywhere
"""
from __future__ import annotations

from repro.data import kvworkload

from .common import N_OBJS, emit, plane_config, run_workload, traffic_bytes

RATIOS = [0.13, 0.25, 0.50, 0.75, 1.0]
WORKLOADS = ["mcd_cl", "mcd_u", "metis", "graph", "df_scan", "ws"]
PLANES = ["hybrid", "paging", "object"]
STEPS = 60
BATCH = 64


def run(quick: bool = False):
    rows = []
    ratios = [0.25, 1.0] if quick else RATIOS
    wls = ["mcd_cl", "df_scan"] if quick else WORKLOADS
    for ratio in ratios:
        for plane in PLANES:
            cfg = plane_config(ratio)
            for wl in wls:
                gen = kvworkload.WORKLOADS[wl](N_OBJS, BATCH, STEPS, seed=1)
                us, stats, _ = run_workload(plane, cfg, gen,
                                            evac_every=16)
                tb = traffic_bytes(cfg, stats)
                rows.append((f"fig4/{wl}/{plane}/local={ratio:.2f}", us,
                             f"traffic_bytes={tb};hits={stats['hits']};"
                             f"obj_ins={stats['obj_ins']};"
                             f"page_ins={stats['page_ins']};"
                             f"lru_scans={stats['lru_scans']};"
                             f"paging_frac={stats['paging_fraction']:.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
