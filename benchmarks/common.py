"""Shared benchmark harness utilities.

All benchmarks run the REAL plane implementations at reduced scale on CPU
and report two measurements per configuration:

  * ``us_per_call``  — measured wall time per access batch (CPU; relative
    comparisons between planes are meaningful, absolutes are not TPU)
  * ``modeled far-memory traffic`` — bytes moved between tiers, the
    hardware-independent quantity behind the paper's I/O-amplification
    results (plus maintenance metadata costs such as LRU scans)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PlaneConfig, create, jitted_access,
                        jitted_advance_epoch, jitted_evacuate,
                        jitted_object_access, jitted_paging_access)
from repro.core import plane as plane_lib

N_OBJS = 2048
OBJ_DIM = 16
PAGE_OBJS = 8


def plane_config(local_ratio: float, *, n_objs=N_OBJS, obj_dim=OBJ_DIM,
                 page_objs=PAGE_OBJS, car_threshold=0.8,
                 lru_scan_budget=0, **kw) -> PlaneConfig:
    data_pages = -(-n_objs // page_objs)
    frames = max(int(data_pages * local_ratio), 6)
    return PlaneConfig(
        num_objs=n_objs, obj_dim=obj_dim, page_objs=page_objs,
        num_frames=frames, num_vpages=data_pages * 3,
        car_threshold=car_threshold, readahead=2,
        lru_scan_budget=lru_scan_budget, **kw)


def make_plane(kind: str, cfg: PlaneConfig):
    data = jnp.zeros((cfg.num_objs, cfg.obj_dim), cfg.dtype)
    s = create(cfg, data)
    if kind == "hybrid":
        fn = jitted_access(cfg)
    elif kind == "paging":
        fn = jitted_paging_access(cfg)
    elif kind == "object":
        fn = jitted_object_access(cfg)
    else:
        raise ValueError(kind)
    return s, fn


def run_workload(kind: str, cfg: PlaneConfig, workload, *,
                 evac_every: int = 0, epoch_every: int = 0):
    """Returns (us_per_batch, stats_dict, final_state).

    ``epoch_every`` > 0 advances the profiling epoch (CAR decay + governor
    PSF recompute, hybrid plane only) every that many batches."""
    s, fn = make_plane(kind, cfg)
    evac = jitted_evacuate(cfg) if kind == "hybrid" else None
    epoch = (jitted_advance_epoch(cfg)
             if kind == "hybrid" and epoch_every else None)
    batches = list(workload)
    # warmup / compile (both the access step and the evacuator — otherwise
    # the hybrid cells mostly measure evacuate's one-off compile time)
    s, out = fn(s, jnp.asarray(batches[0]))
    out.block_until_ready()
    if evac is not None and evac_every:
        jax.block_until_ready(evac(s))  # compile cache only; state discarded
    if epoch is not None:
        jax.block_until_ready(epoch(s))  # compile cache only
    t0 = time.time()
    for i, ids in enumerate(batches):
        s, out = fn(s, jnp.asarray(ids))
        if evac is not None and evac_every and (i + 1) % evac_every == 0:
            s = evac(s)
        if epoch is not None and (i + 1) % epoch_every == 0:
            s = epoch(s)
    out.block_until_ready()
    dt = time.time() - t0
    stats = {k: int(v) for k, v in jax.device_get(s.stats)._asdict().items()}
    stats["paging_fraction"] = float(plane_lib.paging_fraction(cfg, s))
    stats["car_thr"] = float(s.car_thr)
    return dt / len(batches) * 1e6, stats, s


def calibrate_service_time(pcfg: PlaneConfig, plane: str, gen_fn,
                           batch: int, steps: int = 12,
                           n_objs: int = N_OBJS, seed: int = 7) -> float:
    """Mean synchronous-dispatch batch service time (seconds) of one
    serving-engine plane — the anchor for offered-load pacing in the
    latency benchmarks (arrival rate = LOAD_FACTOR / service time)."""
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(EngineConfig(plane=plane, batch=batch, dispatch="sync"),
                 pcfg, jnp.zeros((pcfg.num_objs, pcfg.obj_dim)))
    batches = list(gen_fn(n_objs, batch, steps, seed=seed))
    ts = []
    for b in batches:
        t0 = time.time()
        eng.serve_batch(b)
        ts.append(time.time() - t0)
    # median of the warmed tail: robust to one-off jit/GC/scheduler spikes
    return float(np.median(ts[2:]))


def traffic_bytes(cfg: PlaneConfig, stats: dict) -> int:
    """Far-memory bytes moved (both directions)."""
    return (stats["page_ins"] * cfg.page_bytes
            + stats["obj_ins"] * cfg.row_bytes
            + stats["dirty_page_outs"] * cfg.page_bytes
            + stats["obj_outs"] * cfg.row_bytes)


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
