"""Paper Figs. 5/6: tail latency of the serving engine under the WS
(grouped zipf) and MCD-CL (zipf+churn) workloads, per plane.

Reports p50/p90/p99 request latency at a fixed offered load, 25% local
memory (the paper's latency setup)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.layout import PlaneConfig
from repro.data import kvworkload
from repro.serving.engine import Engine, EngineConfig
from .common import N_OBJS, emit, plane_config


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 120
    for wl_name, gen_fn in [("ws", kvworkload.grouped),
                            ("mcd_cl", kvworkload.zipf_churn)]:
        for plane in ["hybrid", "paging", "object"]:
            pcfg = plane_config(0.25)
            data = jnp.zeros((pcfg.num_objs, pcfg.obj_dim))
            eng = Engine(EngineConfig(plane=plane, batch=64), pcfg, data)
            rep = eng.run(gen_fn(N_OBJS, 64, steps, seed=2))
            lat = rep["latency"]
            rows.append((f"fig56/{wl_name}/{plane}", lat["mean_us"],
                         f"p50_us={lat['p50_us']:.0f};"
                         f"p90_us={lat['p90_us']:.0f};"
                         f"p99_us={lat['p99_us']:.0f};"
                         f"paging_frac={rep['paging_fraction']:.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
