"""Paper Figs. 5/6: tail latency of the serving engine under the WS
(grouped zipf) and MCD-CL (zipf+churn) workloads, per plane.

Reports p50/p90/p99 request latency at a fixed offered load, 25% local
memory (the paper's latency setup).  Each plane is served twice: with
synchronous dispatch (block on every batch — the pre-pipeline engine) and
with the double-buffered plan/execute pipeline; both see the identical
arrival process, so the delta is pure dispatch overlap.  Latency is
charged from each batch's scheduled arrival time, so queueing under
saturation is measured (not hidden in the pacing sleep).

Each row also reports unpaced throughput (``tput_bps`` = batches/s,
saturation drain of the same workload): on a machine whose speed drifts
between calibration and the paced run, the offered load can land on either
side of the saturation knee and swing the tail numbers — the throughput
column is the drift-insensitive measure of what the dispatch overlap buys.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.data import kvworkload
from repro.serving.engine import Engine, EngineConfig
from .common import N_OBJS, calibrate_service_time, emit, plane_config

# offered load: fraction of the calibrated serial service rate — below the
# synchronous engine's saturation point, so the tail measures how each
# dispatch mode absorbs arrival bursts and service jitter rather than an
# unbounded queue.
LOAD_FACTOR = 0.7


def _mk(plane, dispatch, pcfg):
    data = jnp.zeros((pcfg.num_objs, pcfg.obj_dim))
    # "pipelined+bgevac": same double-buffered dispatch, but evacuation is
    # sliced into the dispatch gaps (evac_budget pages per gap) instead of
    # one blocking 16-page foreground compaction per round — the paper's
    # concurrent-evacuator tail-latency discipline.  evac_every=16 so the
    # foreground rounds actually fire inside the quick run.
    kw = (dict(dispatch="pipelined", evac_budget=4)
          if dispatch == "pipelined+bgevac" else dict(dispatch=dispatch))
    return Engine(EngineConfig(plane=plane, batch=64, evac_every=16, **kw),
                  pcfg, data)


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 120
    for wl_name, gen_fn in [("ws", kvworkload.grouped),
                            ("mcd_cl", kvworkload.zipf_churn)]:
        pcfg = plane_config(0.25)
        for plane in ["hybrid", "paging", "object"]:
            # per-plane offered load: the sync-vs-pipelined delta is the
            # point here, so both dispatch modes see the identical arrival
            # process pinned relative to this plane's own service rate
            interarrival = calibrate_service_time(
                pcfg, plane, gen_fn, 64) * LOAD_FACTOR
            modes = ["sync", "pipelined"]
            if plane == "hybrid":
                modes.append("pipelined+bgevac")
            for dispatch in modes:
                # unpaced saturation drain -> throughput
                eng = _mk(plane, dispatch, pcfg)
                t0 = time.time()
                eng.run(gen_fn(N_OBJS, 64, steps, seed=3))
                tput = steps / (time.time() - t0)
                # paced run -> latency distribution at the offered load
                eng = _mk(plane, dispatch, pcfg)
                rep = eng.run(gen_fn(N_OBJS, 64, steps, seed=2),
                              offered_interarrival_s=interarrival)
                lat = rep["latency"]
                rows.append((f"fig56/{wl_name}/{plane}/{dispatch}",
                             lat["mean_us"],
                             f"p50_us={lat['p50_us']:.0f};"
                             f"p90_us={lat['p90_us']:.0f};"
                             f"p99_us={lat['p99_us']:.0f};"
                             f"offered_us={interarrival * 1e6:.0f};"
                             f"tput_bps={tput:.1f};"
                             f"paging_frac={rep['paging_fraction']:.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
