"""Fault-window serving sweep: goodput and tail latency through chaos.

Drives the serving engine through three equal phases — healthy, fault
window, recovery — for each plane (hybrid / paging / object) with the
deterministic fault model of :mod:`repro.core.faults`:

  * ``*/p20_*`` cells: a 20%-transient-failure window (``fail_prob=0.2``
    gated to the middle third of the run), with retries off vs on.  The
    claim under test: goodput inside the window stays >= 0.5x the healthy
    phase, recovers fully after it, and the run never hangs (watchdogged
    retirement, bounded retry queue).
  * ``hybrid/outage_breaker``: a *total* far-tier outage window with the
    circuit breaker armed — the engine flips to degraded paging-local
    serving (hits only), keeps probing, and closes the breaker again
    after the window.
  * ``hybrid/shard_outage_{base,shard,global}``: a SINGLE-shard outage
    over a 2-shard far tier (DESIGN.md §6c).  With the per-shard breaker
    (``breaker_scope="shard"``) only the dead shard trips — the healthy
    shard's serves stay >= 0.9x the fault-free ``_base`` cell
    (``healthy_shard_ratio``) — while the legacy ``"global"`` scope
    degrades both shards on the same schedule.

Each cell reports per-phase goodput (served requests / phase wall) and
served fraction, the overall p99, and the chaos counters; the retry-on
hybrid cell is driven twice with the same seed and the two counter sets
are asserted identical (``det=ok``) — the determinism the whole fault
model promises.

Phases are aligned to the schedule via the engine/device tick mapping:
the engine's warmup access consumes device tick 1, so engine tick ``i``
(1-based) plans at device tick ``i + 1``.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.data import kvworkload
from repro.serving.engine import Engine, EngineConfig

from .common import emit, plane_config


def _drive(plane: str, sched, steps: int, batch: int, pcfg, data, *,
           max_retries: int = 0, breaker: bool = False, shards: int = 1,
           breaker_scope: str = "shard"):
    """Run one engine through the 3-phase workload; returns per-phase
    (offered, served, wall_s) plus the report and chaos counters."""
    ecfg = EngineConfig(plane=plane, batch=batch, dispatch="sync",
                        evac_every=16, faults=sched, shards=shards,
                        max_retries=max_retries, watchdog_s=300.0,
                        breaker_threshold=0.5 if breaker else 0.0,
                        breaker_probe_every=4, breaker_scope=breaker_scope)
    eng = Engine(ecfg, pcfg, data)
    # offer batch-8 new requests per tick: the 8 free tail slots are where
    # queued retries re-enter, so recovery happens in-band, not only at
    # the end-of-run flush
    req = batch - 8
    wl = list(kvworkload.zipf_churn(pcfg.num_objs, req, steps, seed=3))
    b1, b2 = steps // 3, 2 * steps // 3
    marks = {}
    t0 = time.time()
    for i, ids in enumerate(wl, start=1):
        eng.submit(ids)
        eng.drain()
        if i == b1 or i == b2:
            marks[i] = (eng.counters["served"], time.time())
    eng.flush_retries()                 # retries count toward phase C
    marks[steps] = (eng.counters["served"], time.time())
    phases = []
    prev_served, prev_t, prev_i = 0, t0, 0
    for i in (b1, b2, steps):
        srv, t = marks[i]
        phases.append({"offered": (i - prev_i) * req,
                       "served": srv - prev_served,
                       "wall_s": max(t - prev_t, 1e-9)})
        prev_served, prev_t, prev_i = srv, t, i
    return eng, phases


def run(quick: bool = False):
    steps = 45 if quick else 120
    batch = 64
    pcfg = plane_config(0.25)
    data = jnp.zeros((pcfg.num_objs, pcfg.obj_dim), pcfg.dtype)
    b1, b2 = steps // 3, 2 * steps // 3
    # middle third of the run, in device ticks (engine tick i -> i + 1)
    window = (b1 + 2, b2 + 2)
    p20 = faults.Schedule(seed=11, fail_prob=0.2, fail_window=window)
    outage = faults.Schedule(seed=11, outages=(window + (-1,),))

    rows = []

    def cell(name, plane, sched, **kw):
        eng, ph = _drive(plane, sched, steps, batch, pcfg, data, **kw)
        wall = sum(p["wall_s"] for p in ph)
        gp = [p["served"] / p["wall_s"] for p in ph]
        sf = [p["served"] / p["offered"] for p in ph]
        c = eng.counters
        # goodput ratio on served fractions (requests actually answered per
        # request offered): wall-clock rps rides along for context but is
        # CPU-noise-sensitive at bench scale
        rows.append((f"fig_faults/{name}", wall / steps * 1e6,
                     f"gp_healthy_rps={gp[0]:.0f};gp_window_rps={gp[1]:.0f};"
                     f"gp_recover_rps={gp[2]:.0f};"
                     f"sf_healthy={sf[0]:.3f};"
                     f"sf_window={sf[1]:.3f};sf_recover={sf[2]:.3f};"
                     f"window_ratio={sf[1] / max(sf[0], 1e-9):.2f};"
                     f"p99_us={eng.latency.percentile(99):.0f};"
                     f"retries={c['fetch_retries']};"
                     f"shed={c['shed_requests']};"
                     f"degraded={c['degraded_ticks']};"
                     f"trips={c['breaker_trips']}"))
        return eng

    for plane in ["hybrid", "paging", "object"]:
        cell(f"{plane}/p20_noretry", plane, p20)
        eng = cell(f"{plane}/p20_retry", plane, p20, max_retries=4)
        if plane == "hybrid":
            # same-seed replay: chaos accounting must be bit-identical
            eng2, _ = _drive(plane, p20, steps, batch, pcfg, data,
                             max_retries=4)
            det = "ok" if eng.counters == eng2.counters else "MISMATCH"
            name, us, derived = rows[-1]
            rows[-1] = (name, us, derived + f";det={det}")
    cell("hybrid/outage_breaker", "hybrid", outage, max_retries=1,
         breaker=True)

    # per-shard breaker (DESIGN.md §6c): a SINGLE-shard outage over a
    # 2-shard far tier.  scope="shard" trips only shard 0 — shard 1 keeps
    # the fast path and its serves stay >= 0.9x the fault-free baseline —
    # while the legacy scope="global" drags every shard into degraded
    # paging-local serving on the same schedule.
    shard_outage = faults.Schedule(seed=11, outages=((window[0],
                                                      window[1], 0),))
    skw = dict(shards=2, max_retries=1, breaker=True)
    base = cell("hybrid/shard_outage_base", "hybrid", faults.NULL, **skw)
    for scope in ("shard", "global"):
        eng = cell(f"hybrid/shard_outage_{scope}", "hybrid", shard_outage,
                   breaker_scope=scope, **skw)
        healthy = (eng.served_per_shard[1]
                   / max(int(base.served_per_shard[1]), 1))
        name, us, derived = rows[-1]
        rows[-1] = (name, us, derived
                    + f";healthy_shard_ratio={healthy:.3f}"
                    + ";served_per_shard="
                    + str([int(x) for x in eng.served_per_shard]))

    emit(rows)
    return rows


if __name__ == "__main__":
    run()
