"""Prefetch-policy smoke cell: sequential window vs Leap-style
majority-trend stride voting (coverage / accuracy / wasted-fetch ratio).

A strided page scan is the regime Leap built the majority vote for: the
kernel-style sequential window (``prefetch="sequential"``, the seed
readahead policy in plan form) prefetches ``v+1..v+readahead`` and wastes
every fetch once the true stride exceeds the window, while the majority
detector recovers the stride from the deduped miss stream and extrapolates
along the trend.  Stride 1 is the sanity case where both policies should
cover.

Columns (from ``PlaneStats``):
  * ``accuracy``  = prefetch_used / prefetch_issued
  * ``coverage``  = prefetch_used / (prefetch_used + demand page-ins)
                    — the fraction of would-be paging misses the prefetcher
                    absorbed after warmup
  * ``wasted``    = 1 - accuracy (upper bound: still-resident unread
                    prefetches count as wasted)

Cells run the paging baseline (no PSF gating — pure prefetcher policy) and
one hybrid cell (PSF-masked majority prefetch on the churn workload).
"""
from __future__ import annotations

import numpy as np

from repro.data import kvworkload
from .common import N_OBJS, PAGE_OBJS, emit, plane_config, run_workload


def stride_scan(n_objs, batch, steps, stride_pages, page_objs=PAGE_OBJS,
                seed=0):
    """One object per page, pages marching by ``stride_pages`` — the
    deduped miss stream is an arithmetic page sequence."""
    npages = n_objs // page_objs
    pos = 0
    for i in range(steps):
        pages = (pos + np.arange(batch) * stride_pages) % npages
        yield (pages * page_objs + (i % page_objs)).astype(np.int32)
        pos = (pos + batch * stride_pages) % npages


def _derived(stats):
    issued = stats["prefetch_issued"]
    used = stats["prefetch_used"]
    demand = stats["page_ins"] - issued
    acc = used / issued if issued else 0.0
    cov = used / (used + demand) if (used + demand) else 0.0
    return (f"issued={issued};used={used};accuracy={acc:.2f};"
            f"coverage={cov:.2f};wasted={1 - acc:.2f}")


def run(quick: bool = False):
    rows = []
    steps = 30 if quick else 80
    for stride in [1, 3]:
        for mode in ["sequential", "majority"]:
            cfg = plane_config(0.25, prefetch=mode, prefetch_budget=8)
            gen = stride_scan(N_OBJS, 8, steps, stride)
            us, stats, _ = run_workload("paging", cfg, gen)
            rows.append((f"fig_prefetch/stride{stride}/{mode}", us,
                         _derived(stats)))
    # hybrid plane: PSF-masked majority prefetch on the churn workload
    cfg = plane_config(0.25, prefetch="majority", prefetch_budget=8)
    gen = kvworkload.zipf_churn(N_OBJS, 64, steps, seed=8)
    us, stats, _ = run_workload("hybrid", cfg, gen, evac_every=16)
    rows.append(("fig_prefetch/hybrid_churn/majority", us, _derived(stats)))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
