"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
  compute term    = FLOPs / (chips x 197 TFLOP/s)
  memory term     = HBM bytes / (chips x 819 GB/s)
  collective term = wire bytes / (chips-local links x 50 GB/s)

FLOPs/bytes primary source: the analytic model (trip-count exact); the
HLO-measured numbers (scan-body-once) and the HLO-parsed collective bytes
(trip-count corrected) are printed alongside as cross-checks.
"""
from __future__ import annotations

import json
import os

from repro.analysis.analytic import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun.json")


def load(mesh="single"):
    if not os.path.exists(RESULTS):
        return []
    rs = json.load(open(RESULTS))
    return [r for r in rs if r["mesh"] == mesh and r["status"] == "ok"]


def terms(rec) -> dict:
    a = rec["analytic"]
    chips = a["chips"]
    t_c = a["t_compute_s"]
    t_m = a["t_memory_s"]
    # collective: prefer the HLO-parsed wire bytes (per device), corrected;
    # fall back to the analytic estimate
    coll = rec.get("collectives", {})
    wire = coll.get("total_wire_bytes_corrected", 0.0)
    t_x_hlo = wire / ICI_BW if wire else 0.0
    t_x_ana = a["t_collective_s"]
    terms_d = {"compute": t_c, "memory": t_m, "collective": t_x_ana}
    dom = max(terms_d, key=terms_d.get)
    total = sum(terms_d.values())
    return {
        "t_compute_s": t_c, "t_memory_s": t_m,
        "t_collective_s_analytic": t_x_ana, "t_collective_s_hlo": t_x_hlo,
        "bottleneck": dom,
        # fraction of the no-overlap step spent at the binding roofline
        # (1.0 = the binding resource is the whole step; with perfect
        # compute/comm overlap the step collapses to the dominant term)
        "roofline_fraction": terms_d[dom] / max(total, 1e-12),
        "model_flops": a["model_flops_global"],
        "hlo_flops_per_dev": rec.get("cost_analysis", {}).get("flops", 0),
    }


def run(quick: bool = False):
    rows = []
    for rec in sorted(load(), key=lambda r: (r["arch"], r["shape"])):
        t = terms(rec)
        name = f"roofline/{rec['arch']}/{rec['shape']}"
        us = t["t_compute_s"] * 1e6   # "call" = one step at the compute term
        rows.append((name, us,
                     f"t_comp={t['t_compute_s']:.4g};"
                     f"t_mem={t['t_memory_s']:.4g};"
                     f"t_coll={t['t_collective_s_analytic']:.4g};"
                     f"t_coll_hlo={t['t_collective_s_hlo']:.4g};"
                     f"bottleneck={t['bottleneck']};"
                     f"roofline_frac={t['roofline_fraction']:.3f}"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
