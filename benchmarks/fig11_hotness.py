"""Paper Fig. 11: 1-bit access-flag evacuation guidance vs an LRU-like
policy, and vs no guidance at all.

  * atlas      — access-bit hot/cold segregation (the paper's design)
  * atlas-lru  — evacuator guided by exact per-object timestamps (higher
                 accuracy, pays the object-metadata maintenance the paper
                 measures at up to 9%)
  * no-bit     — evacuator moves objects unguided (paper: ~4% fewer pages
                 end up on the paging path)
  * atlas-epoch — atlas segregation + the epoch governor: advance_epoch
                 decays CAR and recomputes PSF online between evacuations;
                 the derived columns record the flips that happened with
                 NO page-out in between (the governor acting on resident
                 pages).
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import access, advance_epoch, evacuate, paging_fraction
from repro.data import kvworkload
from .common import N_OBJS, emit, make_plane, plane_config


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 120
    for variant in ["atlas", "atlas-lru", "no-bit", "atlas-epoch"]:
        cfg = plane_config(0.25)
        s, fn = make_plane("hybrid", cfg)
        evac = jax.jit(partial(evacuate, cfg, garbage_threshold=-1.0))
        epoch = jax.jit(partial(advance_epoch, cfg))
        epoch_flips = 0
        t0 = time.time()
        for i, ids in enumerate(
                kvworkload.zipf_churn(N_OBJS, 64, steps, seed=7)):
            ids = jnp.asarray(ids)
            s, _ = fn(s, ids)
            if variant == "atlas-lru":
                # extra metadata maintenance: exact recency ordering
                s = s._replace(obj_last=s.obj_last.at[ids].set(s.step))
            if variant == "atlas-epoch" and (i + 1) % 8 == 0:
                flips0 = int(s.stats.psf_to_paging + s.stats.psf_to_runtime)
                outs0 = int(s.stats.page_outs)
                s = epoch(s)
                # flips recorded by the epoch itself: page_outs unchanged
                assert int(s.stats.page_outs) == outs0
                epoch_flips += int(s.stats.psf_to_paging
                                   + s.stats.psf_to_runtime) - flips0
            if (i + 1) % 16 == 0:
                if variant == "no-bit":
                    s = evac(s._replace(access=jnp.zeros_like(s.access)))
                elif variant == "atlas-lru":
                    # convert timestamps to access bits: newest 25% are hot
                    thr = s.step - max(steps // 4, 1)
                    va = s.obj_loc
                    hot = s.obj_last >= thr
                    P = cfg.page_objs
                    acc_bits = jnp.zeros_like(s.access).at[
                        va // P, va % P].set(hot)
                    s = evac(s._replace(access=acc_bits))
                else:
                    s = evac(s)
        us = (time.time() - t0) / steps * 1e6
        extra = (f";epoch_flips_no_pageout={epoch_flips};"
                 f"car_thr={float(s.car_thr):.2f}"
                 if variant == "atlas-epoch" else "")
        rows.append((f"fig11/hotness/{variant}", us,
                     f"paging_frac={float(paging_fraction(cfg, s)):.3f};"
                     f"evac_moved={int(s.stats.evac_moved)}" + extra))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
