"""Paper Fig. 7: fraction of pages on the paging path over time — the
adaptive path-switching trace for MCD-CL (churn), graph iteration and the
two-phase Metis workload."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import jitted_evacuate, paging_fraction
from repro.data import kvworkload
from .common import N_OBJS, emit, make_plane, plane_config


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 120
    for wl in ["mcd_cl", "graph", "metis"]:
        cfg = plane_config(0.25)
        s, fn = make_plane("hybrid", cfg)
        evac = jitted_evacuate(cfg, garbage_threshold=0.05)
        # keep the one-off compiles out of the timed trace (results discarded)
        jax.block_until_ready(evac(s))
        jax.block_until_ready(fn(s, jnp.zeros((64,), jnp.int32))[1])
        trace = []
        t0 = time.time()
        for i, ids in enumerate(
                kvworkload.WORKLOADS[wl](N_OBJS, 64, steps, seed=4)):
            s, _ = fn(s, jnp.asarray(ids))
            if (i + 1) % 16 == 0:
                s = evac(s)
            if (i + 1) % max(steps // 8, 1) == 0:
                trace.append(round(float(paging_fraction(cfg, s)), 3))
        us = (time.time() - t0) / steps * 1e6
        rows.append((f"fig7/psf_trace/{wl}", us,
                     "paging_fraction_trace=" + "|".join(map(str, trace))))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
