"""Sharded far tier scaling sweep: shards = 1/2/4/8 x {hybrid, paging}.

Serves the MCD-CL (zipf+churn) workload through the serving engine at each
shard count on 8 simulated host devices and reports unpaced drain
throughput (batches/s) plus p99 request latency.  ``shards=1`` is the
plain single-device engine — the baseline every sharded cell is anchored
to (it must sit within noise of the pre-sharding engine, since the
sharded path only engages at ``shards>1``); ``shards>1`` runs the
round-based all_to_all exchange of ``repro.core.shardplane`` under
shard_map on a ``far`` mesh.

Simulated devices require ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` BEFORE jax initializes, and the parent benchmark process has
long since imported jax — so the sweep runs in a subprocess (the same
discipline as tests/test_dryrun_smoke.py) and ships its rows back as JSON
on the last stdout line.

NOTE: on CPU the shard_map cells pay real collective overhead for
simulated parallelism (all 8 "devices" share the same cores), so
``batches/s`` here measures exchange + dispatch cost, not the bandwidth
scaling a real multi-chip far tier buys.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
params = json.loads(sys.argv[1])
import numpy as np
from benchmarks.common import plane_config
from repro.data import kvworkload
from repro.launch import mesh as mesh_lib
from repro.serving.engine import Engine, EngineConfig
import jax.numpy as jnp

steps, batch = params["steps"], params["batch"]
pcfg = plane_config(0.25)
data = jnp.zeros((pcfg.num_objs, pcfg.obj_dim), pcfg.dtype)
rows = []
for plane in ["hybrid", "paging"]:
    for shards in [1, 2, 4, 8]:
        ecfg = EngineConfig(plane=plane, batch=batch, evac_every=16,
                            shards=shards)
        mesh = mesh_lib.make_far_mesh(shards) if shards > 1 else None
        eng = Engine(ecfg, pcfg, data, mesh=mesh)
        wl = list(kvworkload.zipf_churn(pcfg.num_objs, batch, steps, seed=3))
        t0 = time.time()
        rep = eng.run(iter(wl))
        dt = time.time() - t0
        lat = rep["latency"]
        spills = rep["stats"].get("ingress_spills", 0)
        rows.append([f"fig_shard/{plane}/s{shards}", dt / steps * 1e6,
                     f"tput_bps={steps / dt:.1f};"
                     f"p99_us={lat['p99_us']:.0f};"
                     f"p50_us={lat['p50_us']:.0f};"
                     f"paging_frac={rep['paging_fraction']:.2f};"
                     f"spills={spills}"])
print(json.dumps(rows))
"""


def run(quick: bool = False):
    steps = 30 if quick else 120
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD,
         json.dumps({"steps": steps, "batch": 64})],
        capture_output=True, text=True, env=env, cwd=root, timeout=3000)
    if proc.returncode != 0:
        raise RuntimeError(f"fig_shard child failed:\n{proc.stderr[-4000:]}")
    rows = [tuple(r) for r in json.loads(proc.stdout.strip().split("\n")[-1])]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
