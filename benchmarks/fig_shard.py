"""Sharded far tier scaling sweep: shards = 1/2/4/8 x {hybrid, paging}.

Serves the MCD-CL (zipf+churn) workload through the serving engine at each
shard count on 8 simulated host devices and reports unpaced drain
throughput (batches/s) plus p99 request latency.  ``shards=1`` is the
plain single-device engine — the baseline every sharded cell is anchored
to (it must sit within noise of the pre-sharding engine, since the
sharded path only engages at ``shards>1``); ``shards>1`` runs the
round-based all_to_all exchange of ``repro.core.shardplane`` under
shard_map on a ``far`` mesh.

Two exchange schedules are swept for the hybrid sharded cells:

* ``fig_shard/hybrid/s{N}`` — the default **overlap** schedule (fused
  2-collective rounds, round r+1's ingress issued before round r's
  return rows are collected).
* ``fig_shard/hybrid/s{N}/serial`` — the legacy **serial** schedule
  (3 collectives per round, each round fully retired before the next
  packs).  Comparing the two cells at equal shards is the headline
  overlap-vs-serial throughput number; both produce bit-identical
  results (tests/test_sharded.py holds that line).

Hybrid sharded overlap cells also carry a subtractive per-phase wall
breakdown: ``pack_pct`` times just the per-round pack chain
(``shardplane.jitted_phase_probe(cfg, "pack")``), ``coll_pct`` is the
ingress collective's share (probe "ingress" minus probe "pack"), and
``serve_pct`` is the remainder of the full access step — serve + egress
collective + collect.  The decomposition is approximate (phases overlap
by construction, and XLA fuses across them differently in isolation) but
tracks where wall time goes as shards scale.

Simulated devices require ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` BEFORE jax initializes, and the parent benchmark process has
long since imported jax — so the sweep runs in a subprocess (the same
discipline as tests/test_dryrun_smoke.py) and ships its rows back as JSON
on the last stdout line.

NOTE: on CPU the shard_map cells pay real collective overhead for
simulated parallelism (all 8 "devices" share the same cores), so
``batches/s`` here measures exchange + dispatch cost, not the bandwidth
scaling a real multi-chip far tier buys — and the overlap schedule's win
is understated, since simulated devices cannot actually run a collective
and a serve concurrently.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
params = json.loads(sys.argv[1])
import numpy as np
from benchmarks.common import plane_config
from repro.core import shardplane
from repro.data import kvworkload
from repro.launch import mesh as mesh_lib
from repro.serving.engine import Engine, EngineConfig
import jax
import jax.numpy as jnp

steps, batch = params["steps"], params["batch"]
pcfg = plane_config(0.25)
data = jnp.zeros((pcfg.num_objs, pcfg.obj_dim), pcfg.dtype)


def per_call_us(fn, *args, n=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


rows = []
for plane in ["hybrid", "paging"]:
    for shards in [1, 2, 4, 8]:
        # serial cells only where the exchange actually runs (hybrid,
        # shards>1); paging and s1 have no collective schedule to compare
        exchanges = ["overlap"]
        if plane == "hybrid" and shards > 1:
            exchanges.append("serial")
        for exch in exchanges:
            ecfg = EngineConfig(plane=plane, batch=batch, evac_every=16,
                                shards=shards, shard_exchange=exch)
            mesh = mesh_lib.make_far_mesh(shards) if shards > 1 else None
            wl = list(kvworkload.zipf_churn(pcfg.num_objs, batch, steps,
                                            seed=3))
            # untimed warm run on a throwaway engine: drives every lazily
            # jitted path (evacuation, epoch advance, health probe) far
            # enough to compile, so the timed run measures steady state
            # instead of charging whichever cell compiles first (the
            # caches are keyed on config, which the timed engine shares)
            Engine(ecfg, pcfg, data, mesh=mesh).run(iter(wl[:20]))
            eng = Engine(ecfg, pcfg, data, mesh=mesh)
            t0 = time.time()
            rep = eng.run(iter(wl))
            dt = time.time() - t0
            lat = rep["latency"]
            spills = rep["stats"].get("ingress_spills", 0)
            name = f"fig_shard/{plane}/s{shards}"
            if exch == "serial":
                name += "/serial"
            derived = (f"tput_bps={steps / dt:.1f};"
                       f"p99_us={lat['p99_us']:.0f};"
                       f"p50_us={lat['p50_us']:.0f};"
                       f"paging_frac={rep['paging_fraction']:.2f};"
                       f"spills={spills}")
            if plane == "hybrid" and shards > 1 and exch == "overlap":
                # subtractive phase breakdown on a warm representative
                # batch: pack-only probe, pack+ingress probe, full access
                S, R = shards, eng.scfg.shard_batch
                ids2d = jnp.asarray(
                    np.asarray(wl[0]).reshape(S, R) % pcfg.num_objs,
                    jnp.int32)
                t_pack = per_call_us(
                    shardplane.jitted_phase_probe(eng.scfg, "pack", mesh),
                    ids2d)
                t_ing = per_call_us(
                    shardplane.jitted_phase_probe(eng.scfg, "ingress",
                                                  mesh), ids2d)
                t_full = per_call_us(eng._access, eng.state, ids2d)
                pack = min(t_pack, t_full) / t_full
                coll = min(max(t_ing - t_pack, 0.0), t_full) / t_full
                serve = max(1.0 - pack - coll, 0.0)
                derived += (f";pack_pct={100 * pack:.0f}"
                            f";coll_pct={100 * coll:.0f}"
                            f";serve_pct={100 * serve:.0f}")
            rows.append([name, dt / steps * 1e6, derived])
print(json.dumps(rows))
"""


def run(quick: bool = False):
    steps = 30 if quick else 120
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD,
         json.dumps({"steps": steps, "batch": 64})],
        capture_output=True, text=True, env=env, cwd=root, timeout=3000)
    if proc.returncode != 0:
        raise RuntimeError(f"fig_shard child failed:\n{proc.stderr[-4000:]}")
    rows = [tuple(r) for r in json.loads(proc.stdout.strip().split("\n")[-1])]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
