"""Paper Fig. 9 / Table 2: runtime overhead under 100% local memory.

With everything resident, plane costs are pure overhead: the hybrid plane
pays the read barrier + card profiling; the object plane pays the barrier
+ LRU timestamp maintenance; the paging plane is the near-zero baseline
(kernel-only bookkeeping).  us/batch ratios reproduce the paper's
barrier-overhead ordering."""
from __future__ import annotations

from repro.data import kvworkload
from .common import N_OBJS, emit, plane_config, run_workload


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 100
    base_us = None
    for plane in ["paging", "hybrid", "object"]:
        cfg = plane_config(1.0)            # 100% local
        gen = kvworkload.zipf_churn(N_OBJS, 64, steps, seed=5)
        us, stats, _ = run_workload(plane, cfg, gen)
        if plane == "paging":
            base_us = us
        ovh = (us - base_us) / base_us * 100 if base_us else 0.0
        rows.append((f"fig9/overhead/{plane}", us,
                     f"overhead_vs_paging_pct={ovh:.1f};"
                     f"misses={stats['misses']}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
