"""Paper Fig. 1(c): eviction throughput and maintenance cost, page-granular
(Atlas) vs object-granular (AIFM).

Under identical memory pressure: the hybrid plane's eviction = frame-scan
victim selection + page writes; the object plane's eviction = object-LRU
scan + per-object writes.  We report evicted bytes per wall-second and the
metadata scan volume per evicted byte (the paper's cycles/byte analogue).
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.data import kvworkload
from .common import N_OBJS, emit, plane_config, run_workload, traffic_bytes


def run(quick: bool = False):
    rows = []
    steps = 30 if quick else 80
    for plane in ["hybrid", "object"]:
        cfg = plane_config(0.13)   # heavy pressure
        gen = kvworkload.uniform(N_OBJS, 64, steps, seed=3)
        us, stats, _ = run_workload(plane, cfg, gen)
        out_bytes = (stats["page_outs"] * cfg.page_bytes
                     + stats["obj_outs"] * cfg.row_bytes)
        wall_s = us * steps / 1e6
        scan_per_byte = stats["lru_scans"] / max(out_bytes, 1)
        rows.append((f"fig1c/evict/{plane}", us,
                     f"evicted_bytes={out_bytes};"
                     f"evict_bytes_per_s={out_bytes / max(wall_s, 1e-9):.0f};"
                     f"lru_scans_per_evicted_byte={scan_per_byte:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
