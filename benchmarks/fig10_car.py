"""Paper Fig. 10: CAR-threshold sensitivity.  Sweeps the PSF flip
threshold on the skewed-churn workload at 25% local memory; the paper
finds 80-90% optimal (100% too conservative -> everything stays on the
object path; low values -> premature paging -> I/O amplification)."""
from __future__ import annotations

from repro.data import kvworkload
from .common import N_OBJS, emit, plane_config, run_workload, traffic_bytes


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 100
    ths = [0.5, 0.8] if quick else [0.1, 0.3, 0.5, 0.7, 0.8, 0.9, 1.0]
    for th in ths:
        cfg = plane_config(0.25, car_threshold=th)
        gen = kvworkload.zipf_churn(N_OBJS, 64, steps, seed=6)
        us, stats, _ = run_workload("hybrid", cfg, gen, evac_every=16)
        rows.append((f"fig10/car={th:.1f}", us,
                     f"traffic_bytes={traffic_bytes(cfg, stats)};"
                     f"paging_frac={stats['paging_fraction']:.2f};"
                     f"obj_ins={stats['obj_ins']};page_ins={stats['page_ins']}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
