"""Paper Fig. 10: CAR-threshold sensitivity.  Sweeps the PSF flip
threshold on the skewed-churn workload at 25% local memory; the paper
finds 80-90% optimal (100% too conservative -> everything stays on the
object path; low values -> premature paging -> I/O amplification).

The ``governor`` cells run the adaptive epoch governor instead of a fixed
threshold: ``advance_epoch`` decays the per-page CAR EMA and recomputes
every allocated page's PSF online — the ``epoch_flips`` column counts PSF
flips recorded while page_outs stood still across the measured epochs
(path switching WITHOUT waiting for a page-out), and ``car_thr`` is where
the traffic-balancing control law settled from each starting point."""
from __future__ import annotations

from repro.data import kvworkload
from .common import N_OBJS, emit, plane_config, run_workload, traffic_bytes


def run(quick: bool = False):
    rows = []
    steps = 40 if quick else 100
    ths = [0.5, 0.8] if quick else [0.1, 0.3, 0.5, 0.7, 0.8, 0.9, 1.0]
    for th in ths:
        cfg = plane_config(0.25, car_threshold=th)
        gen = kvworkload.zipf_churn(N_OBJS, 64, steps, seed=6)
        us, stats, _ = run_workload("hybrid", cfg, gen, evac_every=16)
        rows.append((f"fig10/car={th:.1f}", us,
                     f"traffic_bytes={traffic_bytes(cfg, stats)};"
                     f"paging_frac={stats['paging_fraction']:.2f};"
                     f"obj_ins={stats['obj_ins']};page_ins={stats['page_ins']}"))
    # adaptive governor from two starting points: 100% local memory, so
    # after warmup there are no page-outs — every PSF flip in the measured
    # window is the epoch governor acting online
    starts = [0.8] if quick else [0.3, 0.8]
    for th0 in starts:
        cfg = plane_config(1.0, car_threshold=th0)
        gen = kvworkload.zipf_churn(N_OBJS, 64, steps, seed=6)
        us, stats, _ = run_workload("hybrid", cfg, gen, evac_every=16,
                                    epoch_every=8)
        flips = stats["psf_to_paging"] + stats["psf_to_runtime"]
        rows.append((f"fig10/governor_from={th0:.1f}", us,
                     f"traffic_bytes={traffic_bytes(cfg, stats)};"
                     f"paging_frac={stats['paging_fraction']:.2f};"
                     f"car_thr={stats['car_thr']:.2f};"
                     f"epochs={stats['epochs']};epoch_flips={flips};"
                     f"page_outs={stats['page_outs']}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
