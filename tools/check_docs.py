#!/usr/bin/env python
"""Docs lint: README.md / DESIGN.md must not reference things that do
not exist.

Checks, over both files:

  * repo-path references (``src/...``, ``tests/...``, ``benchmarks/...``,
    ``examples/...``, ``.github/...``, ``tools/...``) resolve to real
    files or directories (glob patterns allowed, must match something);
  * root-level doc/artifact basenames (``*.md``, ``*.json``, ``*.toml``)
    exist at the repo root;
  * dotted module references (``repro.core.faults``) resolve under
    ``src/``;
  * every ``§N``/``§Na`` section reference names a section that DESIGN.md
    actually defines.

Exit 0 clean, exit 1 with one line per dangling reference (CI fails).
"""
from __future__ import annotations

import glob
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "DESIGN.md"]

PATH_RE = re.compile(
    r"(?:src|tests|benchmarks|examples|tools|configs|\.github)/"
    r"[\w./*{},-]*[\w*}]")
BASENAME_RE = re.compile(r"`([\w.-]+\.(?:md|json|toml|yml))`")
MODULE_RE = re.compile(r"\brepro(?:\.[a-z_0-9]+)+\b")
SECTION_REF_RE = re.compile(r"§\s*(\d+[a-z]?)")
SECTION_DEF_RE = re.compile(r"^#{2,3}\s+(\d+[a-z]?)[.\s]", re.M)


def defined_sections() -> set[str]:
    return set(SECTION_DEF_RE.findall((ROOT / "DESIGN.md").read_text()))


def check_path(ref: str) -> bool:
    ref = ref.rstrip(".,;:")
    if "{" in ref:          # brace shorthand like fig_{a,b} — expand
        ref = re.sub(r"\{[^}]*\}", "*", ref)
    if "*" in ref:
        return bool(glob.glob(str(ROOT / ref)))
    return (ROOT / ref).exists()


def check_module(ref: str) -> bool:
    p = ROOT / "src" / Path(*ref.split("."))
    return p.is_dir() or p.with_suffix(".py").exists()


def main() -> int:
    problems = []
    sections = defined_sections()
    for doc in DOCS:
        text = (ROOT / doc).read_text()
        for m in PATH_RE.finditer(text):
            if not check_path(m.group(0)):
                problems.append(f"{doc}: dangling path {m.group(0)!r}")
        for m in BASENAME_RE.finditer(text):
            if not (ROOT / m.group(1)).exists():
                problems.append(f"{doc}: dangling file {m.group(1)!r}")
        for m in MODULE_RE.finditer(text):
            if not check_module(m.group(0)):
                problems.append(f"{doc}: dangling module {m.group(0)!r}")
        for m in SECTION_REF_RE.finditer(text):
            if m.group(1) not in sections:
                problems.append(
                    f"{doc}: reference to undefined section §{m.group(1)}")
    for p in sorted(set(problems)):
        print(p)
    if problems:
        print(f"\n{len(set(problems))} dangling reference(s).",
              file=sys.stderr)
        return 1
    print(f"docs lint OK ({', '.join(DOCS)}; "
          f"{len(sections)} DESIGN.md sections)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
