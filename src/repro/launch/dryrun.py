import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective evidence.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json

Per cell this produces:
  * ``compiled.memory_analysis()``  -> bytes/device (argument/output/temp/gen)
  * ``compiled.cost_analysis()``    -> HLO flops / bytes accessed (NOTE:
    while-loop bodies are counted ONCE by XLA — see analysis/analytic.py
    for the trip-count-corrected model; both are recorded)
  * collective operand bytes parsed from the compiled HLO
    (analysis/hlo.py), with while-body multipliers applied.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.analysis import analytic, hlo
from repro.launch import mesh as mesh_lib
from repro.models import api
from repro.optim.optimizers import get_optimizer


def _sharding(mesh, spec_tree):
    return mesh_lib.sharding_tree(mesh, spec_tree)



def _axis_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= _axis_size(mesh, a)
        return n
    if ax == "dp":
        n = mesh.shape.get("data", 1)
        return n * mesh.shape.get("pod", 1)
    if ax == "tp":
        return mesh.shape.get("model", 1)
    return mesh.shape.get(ax, 1)


def arg_bytes_per_device(struct_tree, spec_tree, mesh) -> dict:
    """Per-device bytes of an argument tree under its logical sharding.
    ``host_tier`` separates far-tier slab buffers (leaf path contains
    'slab'): on real hardware these live in host memory
    (memory_kind=pinned_host), not HBM."""
    total = {"device": 0.0, "host_tier": 0.0}

    def walk(struct, spec, path):
        if isinstance(struct, jax.ShapeDtypeStruct):
            div = 1
            if isinstance(spec, tuple):
                for ax in spec:
                    div *= _axis_size(mesh, ax)
            n = 1
            for d in struct.shape:
                n *= d
            b = n * struct.dtype.itemsize / max(div, 1)
            key = "host_tier" if "slab" in path else "device"
            total[key] += b
            return
        if isinstance(struct, dict):
            for k in struct:
                sp = spec[k] if isinstance(spec, dict) else spec
                walk(struct[k], sp, path + "/" + str(k))
            return
        if hasattr(struct, "_fields"):
            for k in struct._fields:
                sp = getattr(spec, k) if hasattr(spec, "_fields") else spec
                walk(getattr(struct, k), sp, path + "/" + k)
            return
        if isinstance(struct, (tuple, list)):
            spc = spec if isinstance(spec, (tuple, list)) and len(spec) == len(struct) and not _is_spec(spec) else [spec] * len(struct)
            for i, s in enumerate(struct):
                walk(s, spc[i], path + f"/{i}")
            return

    def _is_spec(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, (str, tuple)) for e in x)

    walk(struct_tree, spec_tree, "")
    return total


def build_cell(arch: str, shape_name: str, mesh, *, layers_override=None):
    """Returns (fn, example_args, in_shardings, donate) for one cell."""
    cfg = cfgs.get_config(arch)
    if layers_override:
        cfg = analytic.override_layers(cfg, layers_override)
    shape = cfgs.SHAPES[shape_name]
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]

    bs = api.batch_specs(cfg, shape)
    batch_struct = {k: v[0] for k, v in bs.items()}
    batch_spec = {k: v[1] for k, v in bs.items()}

    if shape.kind == "train":
        opt_name = "adafactor" if arch.startswith("kimi") else "adamw"
        opt = get_optimizer(opt_name)
        params_struct = api.param_shapes(cfg)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        step_struct = jax.ShapeDtypeStruct((), jnp.int32)
        fn = api.make_train_step(cfg, opt)
        in_shardings = (
            _sharding(mesh, api.param_pspecs(cfg)),
            _sharding(mesh, api.opt_state_pspecs(cfg, opt_name)),
            mesh_lib.sharding_tree(mesh, None),
            _sharding(mesh, batch_spec),
        )
        args = (params_struct, opt_struct, step_struct, batch_struct)
        specs = (api.param_pspecs(cfg), api.opt_state_pspecs(cfg, opt_name),
                 None, batch_spec)
        return fn, args, in_shardings, (0, 1), specs

    if shape.kind == "prefill":
        fn = api.make_prefill_step(cfg)
        params_struct = api.param_shapes(cfg)
        in_shardings = (_sharding(mesh, api.param_pspecs(cfg)),
                        _sharding(mesh, batch_spec))
        specs = (api.param_pspecs(cfg), batch_spec)
        return fn, (params_struct, batch_struct), in_shardings, (), specs

    # decode / decode_long
    shards = dp if shape.kind == "decode_long" else 1
    fn = api.decode_step(cfg, shape, shards=shards)
    params_struct = api.param_shapes(cfg)
    state_struct = jax.eval_shape(
        lambda: api.init_decode_state(cfg, shape, shards=shards))
    in_shardings = (
        _sharding(mesh, api.param_pspecs(cfg)),
        _sharding(mesh, api.serve_state_pspecs(cfg, shape, shards)),
        _sharding(mesh, batch_spec["tokens"]),
    )
    args = (params_struct, state_struct, batch_struct["tokens"])
    specs = (api.param_pspecs(cfg), api.serve_state_pspecs(cfg, shape, shards),
             batch_spec["tokens"])
    return fn, args, in_shardings, (1,), specs


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             layers_override=None, want_text: bool = False) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "status": "ok"}
    t0 = time.time()
    try:
        fn, args, in_shardings, donate, specs = build_cell(
            arch, shape_name, mesh, layers_override=layers_override)
        acc = {"device": 0.0, "host_tier": 0.0}
        names = ["params", "opt_state", "step", "batch", "serve_state"]
        rec["arg_bytes_per_device"] = {}
        for i, (st, sp) in enumerate(zip(args, specs)):
            ab = arg_bytes_per_device(st, sp, mesh)
            label = ("params" if i == 0 else
                     "arg%d" % i)
            rec["arg_bytes_per_device"][label] = ab
            acc["device"] += ab["device"]
            acc["host_tier"] += ab["host_tier"]
        rec["arg_bytes_per_device"]["total"] = acc
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                k: int(getattr(mem, k, 0) or 0)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")}
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals", "utilization")}
        text = compiled.as_text()
        rec["collectives"] = hlo.collective_summary(text)
        rec["hlo_bytes"] = len(text)
        if want_text:
            rec["hlo_text"] = text
        rec["analytic"] = analytic.cell_model(arch, shape_name, mesh_kind)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="single", choices=["single", "multi",
                                                        "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="results/dryrun.json")
    p.add_argument("--layers", type=int, default=0,
                   help="override layer count (depth probes)")
    p.add_argument("--layout", default="2d", choices=["2d", "fsdp"],
                   help="logical sharding layout (§Perf cell A it.3)")
    args = p.parse_args()
    mesh_lib.set_layout(args.layout)

    todo = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for a, sh, skipped in cfgs.cells():
            for m in meshes:
                todo.append((a, sh, m))
    else:
        for m in meshes:
            todo.append((args.arch, args.shape, m))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok" and not args.layers}

    for a, sh, m in todo:
        if (a, sh, m) in done:
            print(f"[skip cached] {a} {sh} {m}", flush=True)
            continue
        print(f"[dryrun] {a} {sh} {m} ...", flush=True)
        rec = run_cell(a, sh, m, layers_override=args.layers or None)
        print(f"  -> {rec['status']} lower={rec.get('lower_s')}s "
              f"compile={rec.get('compile_s')}s", flush=True)
        if rec["status"] == "fail":
            print(rec["error"], flush=True)
        results = [r for r in results
                   if (r["arch"], r["shape"], r["mesh"]) != (a, sh, m)]
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)

    ok = sum(1 for r in results if r["status"] == "ok")
    print(f"[dryrun] {ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
