"""Serving launcher: batched far-memory KV serving through a chosen data
plane (the Memcached/WebService analogue), or LM token decoding through the
Atlas-paged KV cache.

  # far-memory KV store under the hybrid plane:
  PYTHONPATH=src python -m repro.launch.serve --mode kv --plane hybrid \
      --workload mcd_cl --steps 200

  # LM decode with the plane-managed cache (smoke config):
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3-8b \
      --tokens 32 --batch 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.core.layout import PlaneConfig
from repro.data import kvworkload
from repro.models import api
from repro.serving.engine import Engine, EngineConfig


def serve_kv(args):
    n_objs = args.objects
    data_pages = -(-n_objs // 8)
    pcfg = PlaneConfig(num_objs=n_objs, obj_dim=32, page_objs=8,
                       num_frames=max(int(data_pages * args.local), 8),
                       num_vpages=3 * data_pages, readahead=2)
    data = jnp.arange(n_objs * 32, dtype=jnp.float32).reshape(n_objs, 32)
    eng = Engine(EngineConfig(plane=args.plane, batch=args.batch), pcfg, data)
    wl = kvworkload.WORKLOADS[args.workload](n_objs, args.batch, args.steps,
                                             seed=0)
    rep = eng.run(wl, offered_interarrival_s=args.interarrival)
    print(f"[serve:kv] plane={args.plane} workload={args.workload} "
          f"local={args.local:.0%}")
    print(f"  latency: {rep['latency']}")
    print(f"  stats:   {rep['stats']}")
    print(f"  paging fraction: {rep['paging_fraction']:.2f}")


def serve_lm(args):
    cfg = cfgs.get_smoke(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    shape = cfgs.ShapeConfig("serve", 1024, args.batch, "decode")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = api.init_decode_state(cfg, shape)
    step = jax.jit(api.decode_step(cfg, shape))
    tok = jax.random.randint(jax.random.PRNGKey(1), (args.batch,), 0,
                             cfg.vocab)
    state, logits = step(params, state, tok)   # compile
    t0 = time.time()
    toks = []
    for t in range(args.tokens):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab
        state, logits = step(params, state, tok)
        toks.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"[serve:lm] arch={args.arch} batch={args.batch} "
          f"decoded {args.tokens} tokens in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s)")
    print(f"  sample continuation: {[int(t[0]) for t in toks[:16]]}")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--mode", choices=["kv", "lm"], default="kv")
    # kv mode
    p.add_argument("--plane", default="hybrid",
                   choices=["hybrid", "paging", "object"])
    p.add_argument("--workload", default="mcd_cl",
                   choices=list(kvworkload.WORKLOADS))
    p.add_argument("--objects", type=int, default=4096)
    p.add_argument("--local", type=float, default=0.25)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--interarrival", type=float, default=0.0)
    # lm mode
    p.add_argument("--arch", default="llama3-8b")
    p.add_argument("--tokens", type=int, default=32)
    args = p.parse_args()
    if args.mode == "kv":
        serve_kv(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
