"""Production mesh + logical-axis resolution.

Logical spec axes used throughout the model code:
  * ``"dp"`` — data/FSDP; resolves to ``("pod", "data")`` when a pod axis
    exists, else ``("data",)``.
  * ``"tp"`` — tensor parallel; resolves to ``"model"``.
  * ``"far"`` — the sharded far tier (repro.core.shardplane): a dedicated
    1-D mesh axis over which the hybrid data plane's slab partitions, frame
    pools and profiling state are sharded (``far_specs`` builds the
    PartitionSpec trees for the stacked ``PlaneState``/``KVPlaneState``).

Nothing in this module touches jax device state at import time.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Mesh shaped from the VISIBLE device count (the seed hardcoded
    ``(16, 16)`` / ``(2, 16, 16)`` and failed on anything else).

    The model axis gets the largest power-of-two factor of the device count
    up to 16 (the production TP width); data parallelism takes the rest.
    With ``multi_pod`` a leading pod axis of 2 is split off first when the
    count allows it.  On 256 / 512 devices this reproduces the original
    shapes exactly."""
    n = jax.device_count()
    if multi_pod:
        pods = 2 if n % 2 == 0 and n >= 2 else 1
        per_pod = n // pods
        model = math.gcd(per_pod, 16)
        return jax.make_mesh((pods, per_pod // model, model),
                             ("pod", "data", "model"))
    model = math.gcd(n, 16)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over the locally visible devices (tests / examples)."""
    n = jax.device_count()
    if data * model > n:
        raise ValueError(
            f"make_host_mesh(data={data}, model={model}) needs "
            f"{data * model} devices but only {n} are visible; lower the "
            "mesh size or simulate devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return jax.make_mesh((data, model), ("data", "model"))


def make_far_mesh(shards: int) -> Mesh:
    """1-D mesh over the ``far`` axis for the sharded data plane.  Uses the
    first ``shards`` visible devices (a plane may occupy a submesh)."""
    n = jax.device_count()
    if shards > n:
        raise ValueError(
            f"make_far_mesh(shards={shards}) needs {shards} devices but "
            f"only {n} are visible; lower the shard count or simulate "
            "devices with XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return Mesh(np.asarray(jax.devices()[:shards]), ("far",))


def far_specs(tree):
    """PartitionSpec tree sharding every leaf's leading axis over ``far`` —
    the layout of a stacked ``[shards, ...]`` plane state pytree."""
    return jax.tree.map(lambda _: P("far"), tree)


def put_far(tree, mesh: Mesh):
    """Lay a stacked ``[shards, ...]`` plane pytree out on a ``far`` mesh
    (one shard slice per device) — the device_put every sharded caller
    (engine, tests, benchmarks) used to hand-roll."""
    return jax.device_put(tree, jax.tree.map(
        lambda _: NamedSharding(mesh, P("far")), tree))


# Logical-axis layout: "2d" (default) = FSDP over (pod, data) x TP over
# model; "fsdp" = pure ZeRO-3 over every mesh axis, no tensor parallelism
# (dense-arch training at large global batch — §Perf iteration 3).
_LAYOUT = "2d"


def set_layout(name: str):
    global _LAYOUT
    assert name in ("2d", "fsdp"), name
    _LAYOUT = name


def get_layout() -> str:
    return _LAYOUT


def _axis(mesh: Mesh, logical):
    if logical is None:
        return None
    if logical == "batch":
        # data-parallel batch axis: never includes "model" (batch size may
        # be smaller than the full chip count under the fsdp layout)
        return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if logical == "dp":
        axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        if _LAYOUT == "fsdp":
            axes = axes + ("model",)
        return axes
    if logical == "tp":
        return None if _LAYOUT == "fsdp" else "model"
    return logical


def resolve(mesh: Mesh, spec) -> P:
    """Map a logical spec tuple to a concrete PartitionSpec for ``mesh``."""
    if spec is None:
        return P()
    out = []
    for ax in spec:
        r = _axis(mesh, ax)
        out.append(r)
    return P(*out)


def is_spec(s) -> bool:
    """A logical spec leaf: plain tuple of axis entries (str / None /
    tuple-of-str); NamedTuples (state containers) are NOT leaves."""
    if s is None:
        return True
    if not isinstance(s, tuple) or hasattr(s, "_fields"):
        return False
    return all(e is None or isinstance(e, str)
               or (isinstance(e, tuple)
                   and all(isinstance(x, str) for x in e))
               for e in s)


def resolve_tree(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: resolve(mesh, s), spec_tree, is_leaf=is_spec)


def sharding_tree(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        resolve_tree(mesh, spec_tree),
        is_leaf=lambda s: isinstance(s, P))


def constrain(x, spec):
    """Logical sharding constraint; no-op when tracing without a mesh."""
    try:
        mesh = jax._src.mesh.thread_resources.env.physical_mesh
    except Exception:
        return x
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(mesh, spec)))
