"""Training launcher: end-to-end driver over the orchestrator.

Runs any ``--arch`` (full or smoke config) on the locally visible devices
with the production substrate stack: deterministic step-indexed data,
AdamW/Adafactor, grad accumulation, async fault-tolerant checkpointing,
straggler accounting, restart-resume.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.checkpoint import ckpt as ckpt_lib
from repro.data.synthetic import DataConfig, batch_for_step
from repro.models import api
from repro.optim import accumulated_value_and_grad, get_optimizer
from repro.runtime.orchestrator import (FailureInjector, Orchestrator,
                                        OrchestratorConfig)


def build(cfg, opt, accum: int = 1):
    lf = api.loss(cfg)
    vg = accumulated_value_and_grad(lf, accum)

    def train_step(state, batch):
        params, opt_state, step = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, grads = vg(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params, step)
        return (params, opt_state, step + 1), {"loss": loss, "gnorm": gnorm}

    return jax.jit(train_step, donate_argnums=(0,))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3-8b")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced same-family config")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--fail-at", type=int, nargs="*", default=[],
                   help="inject node failures at these steps (drill)")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args()

    cfg = cfgs.get_smoke(args.arch) if args.smoke else cfgs.get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    opt_name = "adafactor" if args.arch.startswith("kimi") else "adamw"
    from repro.optim.optimizers import cosine_schedule
    opt = get_optimizer(opt_name,
                        lr=cosine_schedule(args.lr, 20, args.steps))

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"opt={opt_name} devices={jax.device_count()}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    frontend = {}
    if cfg.family == "encdec":
        frontend["frames"] = ((max(args.seq // 4, 8), cfg.d_model),
                              np.float32)
    if cfg.frontend == "vision":
        frontend["patches"] = ((cfg.frontend_seq, cfg.frontend_dim),
                               np.float32)

    def batch_fn(step):
        return batch_for_step(dcfg, step, frontend=frontend or None)

    step_fn = build(cfg, opt, args.accum)
    losses = []

    def logging_step(state, batch):
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        step = int(state[2])
        if step % args.log_every == 0:
            tok_s = args.batch * args.seq / (time.time() - t0)
            print(f"  step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} tok/s {tok_s:,.0f}",
                  flush=True)
        return state, metrics

    orch = Orchestrator(
        OrchestratorConfig(ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every),
        logging_step, batch_fn,
        injector=FailureInjector(args.fail_at))
    init_state = (params, opt.init(params), jnp.zeros((), jnp.int32))
    state = orch.run(init_state, args.steps)
    print(f"[train] done: steps={orch.metrics['steps']} "
          f"restarts={orch.metrics['restarts']} "
          f"stragglers={orch.metrics['stragglers']} "
          f"final_loss={losses[-1]:.4f}" if losses else "[train] done")
    return state


if __name__ == "__main__":
    main()
