"""Production tiered KV cache on the Atlas plane (the serve-path fast path).

Two modes, matching the two ends of the paper's spectrum:

* **dense paging mode** (decode_32k): the whole cache fits local; KV lives
  in a paged frame pool indirected by a page table (vLLM-style).  Dense
  decode attention touches every token -> every card bit sets -> CAR = 1 ->
  all pages stay on the paging path.  The always-on CAT profiling still
  runs (its cost is part of what we benchmark).

* **sparse hybrid mode** (long_500k): frames hold only a hot subset of
  pages; the rest live in the far tier (slab).  Each step:
    1. page summaries (kmax/kmin) are scored against q *without fetching*
       (offload-space computation, `kernels.topk_pages`);
    2. the top-k pages are ensured local by the plan-then-execute fetch
       engine: ONE vectorized plan for the whole [B, K] selection
       (``plan_fetch``: per-seq miss ranking, cross-seq dedup, eviction
       victims in a single masked top-k over the shared pool), then all
       page-ins in one batched ``kernels.gather_pages`` call — PSF=paging
       pages arrive whole (bulk DMA), PSF=runtime pages packed to their
       CAT-marked hot rows.  ``fetch_mode="reference"`` replays the
       identical plan through the seed-era scalar loop (the equivalence
       oracle, see tests/test_batch_equivalence.py);
    3. paged flash attention runs over the local pool;
    4. CAT bits are set for the attended rows, and an evicted page's PSF
       is recomputed from CAR at page-out.

The serve loop should enter through ``jitted_attend_sparse`` /
``jitted_sharded_decode``: memoized jit entry points that DONATE the plane
state, so the (huge, unmodified) slab buffers alias through the step
instead of being copied every call.

Everything is static-shaped and vectorized: this is the form of the hybrid
plane that lowers into the multi-pod dry-run.  The fully dynamic
(fault-driven) plane lives in ``repro.core.plane`` and backs the
benchmarks; both implement the same policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import batch as batch_lib
from repro.kernels import ops

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class KVPlaneConfig:
    kv_heads: int
    head_dim: int
    page_tokens: int          # P: tokens per page
    num_pages: int            # NP: logical pages (covers max seq len)
    num_frames: int           # F: local frame pool (== B*NP in dense mode)
    batch: int                # sequences served per shard
    sparse_topk: int = 0      # 0 = dense paging mode; >0 = hybrid sparse
    fetch_budget: int = 8     # pages ensured local per step (sparse mode)
    car_threshold: float = 0.8
    dtype: object = jnp.bfloat16
    # plan-then-execute fetch engine (mirrors PlaneConfig.access_mode):
    fetch_mode: str = "batch"   # "batch" (vectorized) | "reference" (scalar)
    kernel_impl: str = "auto"   # kernels.ops dispatch for the batched movers
    # decode lookahead (mirrors PlaneConfig.prefetch): extend the fetch plan
    # with pages the top-page trajectory is trending toward
    prefetch: str = "none"      # "none" | "sequential" | "majority"
    prefetch_budget: int = 0    # lookahead pages planned per sequence
    # fault model (repro.core.faults.Schedule; None == null schedule):
    # faulted fetches drop out of the plan before victim assignment, so
    # attention proceeds on whatever is resident (graceful degradation)
    faults: object = None

    @property
    def dense(self) -> bool:
        return self.sparse_topk == 0

    @property
    def plan_entries(self) -> int:
        """Fetch-plan length: demand budget + lookahead, per sequence."""
        pf = self.prefetch_budget if self.prefetch != "none" else 0
        return self.batch * (self.fetch_budget + pf)


class KVPlaneState(NamedTuple):
    """Per-layer state (callers stack a leading layer axis and scan)."""
    k_frames: jnp.ndarray   # [KVH, F, P, Dh]
    v_frames: jnp.ndarray   # [KVH, F, P, Dh]
    page_table: jnp.ndarray # [B, NP] int32: logical page -> frame (-1 far)
    # --- far tier + profiling (sparse mode; size-1 placeholders in dense)
    k_slab: jnp.ndarray     # [KVH, B*NP, P, Dh]
    v_slab: jnp.ndarray     # [KVH, B*NP, P, Dh]
    kmax: jnp.ndarray       # [KVH, B*NP, Dh] page summaries (always local)
    kmin: jnp.ndarray       # [KVH, B*NP, Dh]
    cat: jnp.ndarray        # [B, NP, P] bool
    psf: jnp.ndarray        # [B, NP] bool
    hot_hint: jnp.ndarray   # [B, NP, P] bool: CAT snapshot from last residency
    page_rows: jnp.ndarray  # [B, NP] int32: valid rows in the frame copy
    frame_page: jnp.ndarray # [F] int32: frame -> b*NP+page (-1 free)
    clock: jnp.ndarray      # [F] int32
    step: jnp.ndarray       # [] int32


def init(cfg: KVPlaneConfig) -> KVPlaneState:
    KVH, F, P, Dh, B, NP = (cfg.kv_heads, cfg.num_frames, cfg.page_tokens,
                            cfg.head_dim, cfg.batch, cfg.num_pages)
    dense = cfg.dense
    slab_pages = 1 if dense else B * NP
    if dense:
        # fully resident: page (b, j) -> frame b*NP + j
        pt = (jnp.arange(B)[:, None] * NP + jnp.arange(NP)[None, :]).astype(
            jnp.int32)
        frame_page = jnp.arange(B * NP, dtype=jnp.int32)
        assert F == B * NP, "dense mode: frames must cover the cache"
    else:
        pt = jnp.full((B, NP), -1, jnp.int32)
        frame_page = jnp.full((F,), -1, jnp.int32)
    return KVPlaneState(
        k_frames=jnp.zeros((KVH, F, P, Dh), cfg.dtype),
        v_frames=jnp.zeros((KVH, F, P, Dh), cfg.dtype),
        page_table=pt,
        k_slab=jnp.zeros((KVH, slab_pages, P, Dh), cfg.dtype),
        v_slab=jnp.zeros((KVH, slab_pages, P, Dh), cfg.dtype),
        kmax=jnp.full((KVH, slab_pages, Dh), -jnp.inf, jnp.float32),
        kmin=jnp.full((KVH, slab_pages, Dh), jnp.inf, jnp.float32),
        cat=jnp.zeros((B, NP, P), bool),
        psf=jnp.ones((B, NP), bool),
        hot_hint=jnp.zeros((B, NP, P), bool),
        page_rows=jnp.zeros((B, NP), jnp.int32),
        frame_page=frame_page,
        clock=jnp.zeros((F,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


# --------------------------------------------------------------------------
# dense paging mode
# --------------------------------------------------------------------------

def append_dense(cfg: KVPlaneConfig, s: KVPlaneState, k_new, v_new, lengths):
    """Write one new token per sequence.  k/v_new: [B, KVH, Dh];
    lengths: [B] current lengths (token goes at index lengths[b])."""
    B, P = cfg.batch, cfg.page_tokens
    page = lengths // P
    slot = lengths % P
    frame = s.page_table[jnp.arange(B), page]            # [B]
    kf = s.k_frames.at[:, frame, slot].set(
        k_new.transpose(1, 0, 2).astype(cfg.dtype))
    vf = s.v_frames.at[:, frame, slot].set(
        v_new.transpose(1, 0, 2).astype(cfg.dtype))
    return s._replace(k_frames=kf, v_frames=vf, step=s.step + 1)


def attend_dense(cfg: KVPlaneConfig, s: KVPlaneState, q, lengths):
    """q: [B, H, Dh] -> [B, H, Dh] via paged attention over the frame pool.
    Also runs the always-on CAT profiling (dense touch -> CAR -> 1)."""
    P, NP = cfg.page_tokens, cfg.num_pages
    page_lens = ops.lengths_to_page_lens(lengths, NP, P)
    out, _used = ops.paged_attention(q, s.k_frames, s.v_frames, s.page_table,
                                     page_lens)
    # profiling: dense attention reads every position below length — the
    # program touched every card (CAR -> 1, pages stay on the paging path)
    pos = (jnp.arange(NP * P)).reshape(NP, P)
    touched = pos[None] < lengths[:, None, None]          # [B, NP, P]
    s = s._replace(cat=jnp.logical_or(s.cat, touched),
                   page_rows=page_lens,
                   clock=jnp.full_like(s.clock, s.step))
    return out, s


# --------------------------------------------------------------------------
# sparse hybrid mode (the Atlas showcase)
# --------------------------------------------------------------------------

def write_page_to_slab(cfg: KVPlaneConfig, s: KVPlaneState, b: int,
                       page_idx, k_page, v_page):
    """Prefill helper: place a full page [KVH, P, Dh] in the far tier and
    update its summaries."""
    gp = b * cfg.num_pages + page_idx
    ks = lax.dynamic_update_index_in_dim(s.k_slab, k_page, gp, axis=1)
    vs = lax.dynamic_update_index_in_dim(s.v_slab, v_page, gp, axis=1)
    kmax = s.kmax.at[:, gp].set(k_page.max(axis=1).astype(jnp.float32))
    kmin = s.kmin.at[:, gp].set(k_page.min(axis=1).astype(jnp.float32))
    return s._replace(k_slab=ks, v_slab=vs, kmax=kmax, kmin=kmin)


class KVFetchPlan(NamedTuple):
    """Fixed-shape ingress plan for one sparse decode step: one entry per
    (sequence, budget slot), N = batch * fetch_budget.  Shapes depend only
    on the config, so a serving host can enqueue the next step's plan while
    the previous step executes (see serving.engine)."""
    seq: jnp.ndarray     # [N] int32 owning sequence
    page: jnp.ndarray    # [N] int32 logical page to fetch (-1 = no-op)
    victim: jnp.ndarray  # [N] int32 destination frame (distinct entries)


def _lookahead_candidates(cfg: KVPlaneConfig, s: KVPlaneState,
                          tops: jnp.ndarray) -> jnp.ndarray:
    """Decode-lookahead section of the fetch plan: ``[B, Qp]`` pages the
    top-page trajectory is trending toward (-1 pad).

    ``prefetch="sequential"`` extrapolates past the newest selected page
    (decode appends march forward).  ``prefetch="majority"`` runs the
    Leap-style vote over the deltas of the (sorted) selected pages — a
    strided retrieval pattern extrapolates along its dominant stride, with
    the most recent delta as the no-majority fallback.  Candidates are
    masked to valid, currently-missing, PSF=paging pages not already in
    the selection (a packed runtime-path page is cheaper to fetch on
    demand than to page in whole speculatively)."""
    B, K = tops.shape
    NP, Qp = cfg.num_pages, cfg.prefetch_budget

    def per_seq(b):
        sel = tops[b]
        valid = sel >= 0
        nv = jnp.sum(valid.astype(jnp.int32))
        srt = jnp.sort(jnp.where(valid, sel, jnp.iinfo(jnp.int32).max))
        if cfg.prefetch == "sequential":
            stride = jnp.asarray(1, jnp.int32)
            have = nv >= 1
        else:  # "majority"
            stride, have = batch_lib.majority_stride(
                srt[1:] - srt[:-1], jnp.maximum(nv - 1, 0))
        base = srt[jnp.clip(nv - 1, 0, K - 1)]
        k = jnp.arange(1, Qp + 1, dtype=jnp.int32)
        cand = jnp.where(have, base + k * stride, -1)
        ok = (cand >= 0) & (cand < NP)
        safe = jnp.clip(cand, 0, NP - 1)
        ok &= s.page_table[b, safe] < 0          # currently missing
        ok &= s.psf[b, safe]                     # PSF mask: paging pages only
        ok &= ~jnp.any(cand[:, None] == sel[None, :], axis=1)
        return jnp.where(ok, cand, -1)

    return jax.vmap(per_seq)(jnp.arange(B))


def plan_fetch(cfg: KVPlaneConfig, s: KVPlaneState, tops: jnp.ndarray
               ) -> KVFetchPlan:
    """Build ONE vectorized fetch plan for the whole ``[B, K]`` top-page
    selection: per-sequence hit/miss classification, first-``fetch_budget``
    miss selection (stable score-rank order), an optional decode-lookahead
    section (``cfg.prefetch``/``cfg.prefetch_budget`` — the same planner
    discipline as ``batch.plan_access``), cross-sequence dedup of the
    flattened global page ids, and eviction victims chosen in a single
    masked top-k over the shared frame pool (wanted-resident frames are
    pinned out of the candidate set; a fetch with no unpinned victim left
    is dropped, lookahead entries first since they rank last)."""
    F, NP = cfg.num_frames, cfg.num_pages
    B, K = tops.shape
    Qp = cfg.prefetch_budget if cfg.prefetch != "none" else 0
    N = cfg.plan_entries
    if N > F:
        raise ValueError(
            f"batch*(fetch_budget+prefetch_budget)={N} fetches per step "
            f"need at least that many frames (have {F})")

    valid = tops >= 0
    safe = jnp.maximum(tops, 0)
    frames_of = s.page_table[jnp.arange(B)[:, None], safe]       # [B, K]
    resident = valid & (frames_of >= 0)
    missing = valid & (frames_of < 0)

    # first `fetch_budget` missing pages per sequence (stable rank order)
    order = jnp.argsort(~missing, axis=1)                        # missing first
    sel = jnp.take_along_axis(tops, order, axis=1)[:, :cfg.fetch_budget]
    selm = jnp.take_along_axis(missing, order, axis=1)[:, :cfg.fetch_budget]
    page = jnp.where(selm, sel, -1).reshape(B * cfg.fetch_budget)
    seq = jnp.repeat(jnp.arange(B, dtype=jnp.int32), cfg.fetch_budget)
    if Qp:
        # ALL demand entries precede ALL lookahead entries in the flat
        # plan, so rank-ordered victim assignment (and the drop-on-
        # pressure tail) favors every sequence's demand over any
        # sequence's speculation
        page = jnp.concatenate(
            [page, _lookahead_candidates(cfg, s, tops).reshape(B * Qp)])
        seq = jnp.concatenate(
            [seq, jnp.repeat(jnp.arange(B, dtype=jnp.int32), Qp)])

    # cross-sequence dedup on the flattened global page ids (defensive: a
    # duplicated selection must not schedule two fetches into two frames)
    gp = seq * NP + page
    i = jnp.arange(N, dtype=jnp.int32)
    ok = page >= 0
    same = (gp[None, :] == gp[:, None]) & ok[None, :]
    first = jnp.min(jnp.where(same, i[None, :], N), axis=1) == i
    page = jnp.where(ok & first, page, -1)

    # fault model (repro.core.faults): a faulted remote fetch drops out of
    # the plan HERE — before victim assignment — so it never claims a frame
    # or evicts anything; attention simply proceeds on what is resident
    # (the sparse path's score masking already tolerates missing pages)
    fc = cfg.faults
    if fc is not None and fc.active:
        okf = page >= 0
        fail = okf & fc.fetch_fail(s.step + 1,
                                   seq * NP + jnp.maximum(page, 0))
        page = jnp.where(fail, -1, page)

    # victims: one masked top-k over the shared pool; every wanted-resident
    # frame is pinned (the soft-pin analogue made hard by the mask).  The
    # coldest victims are compacted onto the VALID fetch entries — a no-op
    # slot (a sequence with fewer misses than budget) must not absorb a
    # cold frame while a real fetch is pushed onto a warm or pinned one.
    # A fetch whose victim would be pinned is dropped instead of executed.
    INF = jnp.iinfo(jnp.int32).max
    pinned = jnp.zeros((F,), bool).at[
        jnp.where(resident, frames_of, F).reshape(-1)].set(True)
    score = jnp.where(pinned, INF, s.clock)
    neg, victims = lax.top_k(-score, N)                          # distinct
    ok = page >= 0
    rank = jnp.cumsum(ok.astype(jnp.int32)) - 1
    usable = ok & ((-neg)[jnp.clip(rank, 0, N - 1)] < INF)
    page = jnp.where(usable, page, -1)
    victim = victims[jnp.where(usable, rank, N - 1)]
    return KVFetchPlan(seq=seq, page=page, victim=victim)


def _evict_math(cfg: KVPlaneConfig, cat_now, old_hint, old_rows):
    """PSF + hot-hint recomputation at page-out (shared by both executors).

    KV pages are append-only and appends write through to the slab, so
    frames are never dirty: page-out is metadata-only.  PSF is recomputed
    from CAR over the FULL page ("would fetching the whole page have been
    worth it?") — a packed runtime page has at most n_hot marked cards, so
    it keeps taking the runtime path.  The hot-set snapshot maps packed
    card bits back through the previous hint (packed slot i == i-th set bit
    of the old hint, by stable sort)."""
    P = cfg.page_tokens
    car = jnp.mean(cat_now.astype(jnp.float32), axis=-1)
    rank = jnp.cumsum(old_hint.astype(jnp.int32), axis=-1) - 1
    packed_back = jnp.logical_and(
        old_hint,
        jnp.take_along_axis(cat_now, jnp.clip(rank, 0, P - 1), axis=-1))
    was_full = old_rows >= P
    hint = jnp.where(was_full[..., None], cat_now, packed_back)
    return car >= cfg.car_threshold, hint


def _ingress_math(cfg: KVPlaneConfig, psf, hot, page_fill):
    """Fetch-path selection (shared by both executors): paging for
    first-touch/append pages and PSF=paging pages, else a runtime packing
    permutation that moves the CAT-marked hot rows to the front (decode
    attention is KV-permutation-invariant)."""
    P = cfg.page_tokens
    n_hot = jnp.sum(hot.astype(jnp.int32), axis=-1)
    take_paging = jnp.logical_or(psf, n_hot == 0)
    perm = jnp.argsort(~hot, axis=-1)                    # stable: hot rows first
    perm = jnp.where(take_paging[..., None],
                     jnp.broadcast_to(jnp.arange(P, dtype=perm.dtype),
                                      perm.shape), perm)
    rows = jnp.where(take_paging, page_fill, n_hot).astype(jnp.int32)
    return perm, rows


def _exec_fetch_batch(cfg: KVPlaneConfig, s: KVPlaneState,
                      plan: KVFetchPlan, fills: jnp.ndarray) -> KVPlaneState:
    """Execute the whole plan with batched data movement: all page-outs as
    one set of masked scatters, all page-ins (whole pages AND packed
    hot-row fetches) as ONE ``kernels.gather_rows`` call per KV tensor.

    Safe to vectorize because the plan's touched page sets are disjoint:
    victims are distinct frames, evicted pages are currently resident,
    fetched pages are currently missing — so every scatter below hits a
    distinct (b, page) slot and all reads can happen against entry state
    (bit-identical to the scalar replay, enforced by the equivalence
    tests)."""
    P, NP, F = cfg.page_tokens, cfg.num_pages, cfg.num_frames
    b, pg, f = plan.seq, plan.page, plan.victim
    N = pg.shape[0]
    ok = pg >= 0
    safe_pg = jnp.maximum(pg, 0)

    # ---- page-out (metadata-only; egress is always page-granular) -------
    old_gp = s.frame_page[f]                             # [N]
    evict = ok & (old_gp >= 0)
    old_safe = jnp.maximum(old_gp, 0)
    old_b, old_pg = old_safe // NP, old_safe % NP
    cat_now = s.cat[old_b, old_pg]                       # [N, P]
    new_psf, hint = _evict_math(cfg, cat_now, s.hot_hint[old_b, old_pg],
                                s.page_rows[old_b, old_pg])
    eidx = jnp.where(evict, old_safe, NP * cfg.batch)   # OOB scatter = drop
    psf = s.psf.reshape(-1).at[eidx].set(new_psf).reshape(s.psf.shape)
    hot_hint = s.hot_hint.reshape(-1, P).at[eidx].set(hint).reshape(
        s.hot_hint.shape)
    cat = s.cat.reshape(-1, P).at[eidx].set(False)
    page_rows = s.page_rows.reshape(-1).at[eidx].set(0)
    page_table = s.page_table.reshape(-1).at[eidx].set(-1)

    # ---- page-in: one batched row gather per KV tensor ------------------
    gp_new = b * NP + safe_pg
    perm, rows_new = _ingress_math(cfg, s.psf[b, safe_pg],
                                   s.hot_hint[b, safe_pg],
                                   fills[b, safe_pg])
    # invalid entries' pages never land (their scatter index is dropped),
    # so the gather can skip the zero-fill pass entirely
    kpages = ops.gather_pages(s.k_slab, gp_new, perm, impl=cfg.kernel_impl,
                              masked=False)
    vpages = ops.gather_pages(s.v_slab, gp_new, perm, impl=cfg.kernel_impl,
                              masked=False)
    fdst = jnp.where(ok, f, F)
    # frame-pool insert: leading-axis scatter on the [KVH*F, P*Dh] page
    # view — one page-sized update window per (head, fetch), O(N) traffic
    # (an axis-1 scatter or a full-pool rebuild both measure slower)
    Dh = cfg.head_dim
    fidx = jnp.where(ok[None, :], jnp.arange(cfg.kv_heads, dtype=jnp.int32
                                             )[:, None] * F + fdst[None],
                     cfg.kv_heads * F).reshape(-1)
    k_frames = s.k_frames.reshape(cfg.kv_heads * F, P * Dh).at[fidx].set(
        kpages.reshape(cfg.kv_heads * N, P * Dh)).reshape(s.k_frames.shape)
    v_frames = s.v_frames.reshape(cfg.kv_heads * F, P * Dh).at[fidx].set(
        vpages.reshape(cfg.kv_heads * N, P * Dh)).reshape(s.v_frames.shape)

    iidx = jnp.where(ok, gp_new, NP * cfg.batch)
    page_table = page_table.at[iidx].set(f).reshape(s.page_table.shape)
    page_rows = page_rows.at[iidx].set(rows_new).reshape(s.page_rows.shape)
    # CAT cleared at page-in ("accessed since last swapped in"); the
    # profiling step marks attended rows afterwards
    cat = cat.at[iidx].set(False).reshape(s.cat.shape)
    frame_page = s.frame_page.at[fdst].set(gp_new)
    clock = s.clock.at[fdst].set(s.step)
    return s._replace(k_frames=k_frames, v_frames=v_frames,
                      page_table=page_table, page_rows=page_rows, cat=cat,
                      psf=psf, hot_hint=hot_hint, frame_page=frame_page,
                      clock=clock)


def _exec_fetch_reference(cfg: KVPlaneConfig, s: KVPlaneState,
                          plan: KVFetchPlan, fills: jnp.ndarray
                          ) -> KVPlaneState:
    """Scalar oracle: replay the identical plan one fetch at a time (the
    seed-era `_evict_and_fetch` body driven by the shared plan)."""
    P, NP = cfg.page_tokens, cfg.num_pages
    N = plan.page.shape[0]

    def fetch_one(j, s):
        b, pg, f = plan.seq[j], plan.page[j], plan.victim[j]

        def do(s):
            old_gp = s.frame_page[f]
            old_b, old_pg = old_gp // NP, old_gp % NP

            def evict(s):
                new_psf, hint = _evict_math(
                    cfg, s.cat[old_b, old_pg][None],
                    s.hot_hint[old_b, old_pg][None],
                    s.page_rows[old_b, old_pg][None])
                return s._replace(
                    psf=s.psf.at[old_b, old_pg].set(new_psf[0]),
                    hot_hint=s.hot_hint.at[old_b, old_pg].set(hint[0]),
                    cat=s.cat.at[old_b, old_pg].set(False),
                    page_rows=s.page_rows.at[old_b, old_pg].set(0),
                    page_table=s.page_table.at[old_b, old_pg].set(-1))

            s = lax.cond(old_gp >= 0, evict, lambda s: s, s)

            gp = b * NP + pg
            kpage = lax.dynamic_index_in_dim(s.k_slab, gp, 1, keepdims=False)
            vpage = lax.dynamic_index_in_dim(s.v_slab, gp, 1, keepdims=False)
            perm, rows = _ingress_math(
                cfg, s.psf[b, pg][None], s.hot_hint[b, pg][None],
                fills[b, pg][None])
            kpage = jnp.take(kpage, perm[0], axis=1)
            vpage = jnp.take(vpage, perm[0], axis=1)
            kf = lax.dynamic_update_index_in_dim(s.k_frames, kpage, f, 1)
            vf = lax.dynamic_update_index_in_dim(s.v_frames, vpage, f, 1)
            return s._replace(
                k_frames=kf, v_frames=vf,
                page_table=s.page_table.at[b, pg].set(f),
                page_rows=s.page_rows.at[b, pg].set(rows[0]),
                frame_page=s.frame_page.at[f].set(gp),
                cat=s.cat.at[b, pg].set(False),
                clock=s.clock.at[f].set(s.step))

        return lax.cond(pg >= 0, do, lambda s: s, s)

    return lax.fori_loop(0, N, fetch_one, s)


def fetch_pages(cfg: KVPlaneConfig, s: KVPlaneState, tops: jnp.ndarray,
                fills: jnp.ndarray, *, mode: str | None = None
                ) -> KVPlaneState:
    """Plan-then-execute ingress for a ``[B, K]`` page selection.

    ``fills`` [B, NP]: appended tokens per page (bounds the valid rows of
    paging fetches).  ``mode`` selects the executor ("batch" | "reference",
    default ``cfg.fetch_mode``); both replay the identical plan."""
    mode = mode or cfg.fetch_mode
    if mode not in ("batch", "reference"):
        raise ValueError(f"unknown fetch mode: {mode!r}")
    plan = plan_fetch(cfg, s, tops)
    if mode == "reference":
        return _exec_fetch_reference(cfg, s, plan, fills)
    return _exec_fetch_batch(cfg, s, plan, fills)


def attend_sparse(cfg: KVPlaneConfig, s: KVPlaneState, q, lengths, *,
                  mode: str | None = None):
    """Hybrid sparse decode.  q: [B, H, Dh] (B = 1 per shard in long_500k).

    Returns (out [B, H, Dh], state)."""
    B, P, NP = cfg.batch, cfg.page_tokens, cfg.num_pages
    K = cfg.sparse_topk
    s = s._replace(step=s.step + 1)

    # 1. offload-space scoring against far-resident summaries
    scores = ops.page_scores(q, s.kmax.reshape(cfg.kv_heads, -1, cfg.head_dim),
                             s.kmin.reshape(cfg.kv_heads, -1, cfg.head_dim))
    # scores: [B, KVH, B*NP] -> per-sequence slice, reduce over kv heads
    per_page = scores.max(axis=1)                        # [B, B*NP]

    def seq_sel(b):
        sl = lax.dynamic_slice_in_dim(per_page[b], b * NP, NP)
        npages = jnp.maximum((lengths[b] + P - 1) // P, 1)
        valid = jnp.arange(NP) < npages
        sl = jnp.where(valid, sl, -jnp.inf)
        _, top = lax.top_k(sl, K)
        top = jnp.where(jnp.arange(K) < jnp.minimum(npages, K), top, -1)
        # always include the newest page (it is being appended)
        newest = npages - 1
        present = jnp.any(top == newest)
        top = top.at[K - 1].set(jnp.where(present, top[K - 1], newest))
        return top

    tops = jax.vmap(seq_sel)(jnp.arange(B))              # [B, K]

    # 2. ensure-local with static fetch budget (ingress via PSF): one
    #    vectorized plan for the whole [B, K] selection, batched execution
    fills = ops.lengths_to_page_lens(lengths, NP, P)      # [B, NP]
    s = fetch_pages(cfg, s, tops, fills, mode=mode)

    # 3. attention over the selected local pages only (columns = selection;
    #    per-column row counts come from page_rows — packed pages included)
    bidx = jnp.arange(B)[:, None]
    sel_frames = s.page_table[bidx, tops]                # [B, K] (-1 if miss)
    sel_valid = sel_frames >= 0
    sel_rows = jnp.where(sel_valid, s.page_rows[bidx, tops], 0)
    out, used = ops.paged_attention(
        q, s.k_frames, s.v_frames,
        jnp.where(sel_valid, sel_frames, -1), sel_rows)

    # 4. always-on profiling: mark the cards of rows whose attention weight
    #    was above the within-page mean (``used`` from the attention kernel)
    #    — flat pages mark everything -> CAR high -> paging; skewed pages
    #    mark the few heavy rows -> CAR low -> runtime
    touched_pages = jnp.where(sel_valid, tops, 0)
    cat = s.cat.at[bidx, touched_pages].set(
        jnp.where(sel_valid[..., None],
                  jnp.logical_or(s.cat[bidx, touched_pages], used),
                  s.cat[bidx, touched_pages]))
    clock = s.clock.at[jnp.maximum(sel_frames, 0).reshape(-1)].set(
        jnp.where(sel_valid.reshape(-1), s.step,
                  s.clock[jnp.maximum(sel_frames, 0).reshape(-1)]))
    return out, s._replace(cat=cat, clock=clock)


# --------------------------------------------------------------------------
# window (ring-buffer) mode: sliding-window attention at long context
# --------------------------------------------------------------------------

def append_window(cfg: KVPlaneConfig, s: KVPlaneState, k_new, v_new, lengths):
    """Ring-buffer append for SWA (mixtral long_500k): the cache covers only
    the window; new tokens overwrite the oldest slot."""
    W = cfg.num_pages * cfg.page_tokens
    return append_dense(cfg, s, k_new, v_new, lengths % W)


def attend_window(cfg: KVPlaneConfig, s: KVPlaneState, q, lengths):
    """Attention over the ring buffer: every resident slot is inside the
    window by construction (older tokens were overwritten)."""
    W = cfg.num_pages * cfg.page_tokens
    return attend_dense(cfg, s, q, jnp.minimum(lengths, W))


# --------------------------------------------------------------------------
# sharded sparse decode: plane shards own disjoint page ranges; partial
# attention per shard, log-sum-exp combine across shards (flash-decoding)
# --------------------------------------------------------------------------

def _attend_pages_partial(q, k_frames, v_frames, table, rows):
    """Unnormalized attention over selected local pages.

    q [B, H, Dh]; k/v_frames [KVH, F, P, Dh]; table/rows [B, K].
    Returns (acc [B, H, Dh] f32, m [B, H, 1], l [B, H, 1],
             used [B, K, P] bool)."""
    B, H, Dh = q.shape
    KVH, F, P, _ = k_frames.shape
    K = table.shape[1]
    G = H // KVH

    def per_seq(qb, pt, pr):
        safe = jnp.maximum(pt, 0)
        k = k_frames[:, safe].reshape(KVH, K * P, Dh)
        v = v_frames[:, safe].reshape(KVH, K * P, Dh)
        qg = qb.reshape(KVH, G, Dh).astype(jnp.float32)
        sc = jnp.einsum("kgd,ksd->kgs", qg, k.astype(jnp.float32))
        sc *= 1.0 / jnp.sqrt(jnp.float32(Dh))
        row = jnp.tile(jnp.arange(P), K)
        valid = (row < jnp.repeat(pr, P)) & jnp.repeat(pt >= 0, P)
        sc = jnp.where(valid[None, None, :], sc, NEG_INF)
        m = sc.max(-1, keepdims=True)                    # [KVH, G, 1]
        p = jnp.exp(sc - m)
        p = jnp.where(valid[None, None, :], p, 0.0)
        l = p.sum(-1, keepdims=True)
        acc = jnp.einsum("kgs,ksd->kgd", p, v.astype(jnp.float32))
        # card signal: weight above within-page mean
        pp = p.reshape(KVH, G, K, P)
        mass = pp.sum(-1, keepdims=True)
        used = (pp * P > mass).any(axis=(0, 1)) & valid.reshape(K, P)
        return (acc.reshape(H, Dh), m.reshape(H, 1), l.reshape(H, 1), used)

    return jax.vmap(per_seq)(q, table, rows)


def attend_sparse_partial(cfg: KVPlaneConfig, s: KVPlaneState, q,
                          first_token, global_len, newest_page, *,
                          mode: str | None = None):
    """One shard's contribution to sharded sparse decode.

    ``first_token``: absolute position of this shard's first page;
    ``global_len``: sequence length; ``newest_page``: local index of the
    append page (-1 if another shard owns it).  Returns (acc, m, l, s)."""
    B, P, NP = cfg.batch, cfg.page_tokens, cfg.num_pages
    K = cfg.sparse_topk
    s = s._replace(step=s.step + 1)
    page_fill = jnp.clip(global_len - first_token - jnp.arange(NP) * P, 0, P
                         ).astype(jnp.int32)
    n_valid_pages = jnp.sum((page_fill > 0).astype(jnp.int32))

    scores = ops.page_scores(q, s.kmax.reshape(cfg.kv_heads, -1, cfg.head_dim),
                             s.kmin.reshape(cfg.kv_heads, -1, cfg.head_dim))
    per_page = scores.max(axis=1)                        # [B, B*NP]

    def seq_sel(b):
        sl = lax.dynamic_slice_in_dim(per_page[b], b * NP, NP)
        valid = jnp.arange(NP) < n_valid_pages
        sl = jnp.where(valid, sl, -jnp.inf)
        _, top = lax.top_k(sl, K)
        top = jnp.where(jnp.arange(K) < jnp.minimum(n_valid_pages, K),
                        top, -1)
        # the append page must stay selected on its owner shard; if the
        # scorer didn't pick it, it replaces the lowest-score selection
        present = jnp.logical_or(jnp.any(top == newest_page),
                                 newest_page < 0)
        top = top.at[K - 1].set(jnp.where(present, top[K - 1], newest_page))
        return top

    tops = jax.vmap(seq_sel)(jnp.arange(B))              # [B, K]

    fills = jnp.broadcast_to(page_fill[None], (B, NP))
    s = fetch_pages(cfg, s, tops, fills, mode=mode)

    bidx = jnp.arange(B)[:, None]
    safe_tops = jnp.maximum(tops, 0)
    sel_frames = jnp.where(tops >= 0, s.page_table[bidx, safe_tops], -1)
    sel_valid = sel_frames >= 0
    sel_rows = jnp.where(sel_valid, s.page_rows[bidx, safe_tops], 0)
    acc, m, l, used = _attend_pages_partial(
        q, s.k_frames, s.v_frames,
        jnp.where(sel_valid, sel_frames, -1), sel_rows)

    touched = jnp.where(sel_valid, tops, 0)
    cat = s.cat.at[bidx, touched].set(
        jnp.where(sel_valid[..., None],
                  jnp.logical_or(s.cat[bidx, touched], used),
                  s.cat[bidx, touched]))
    clock = s.clock.at[jnp.maximum(sel_frames, 0).reshape(-1)].set(
        jnp.where(sel_valid.reshape(-1), s.step,
                  s.clock[jnp.maximum(sel_frames, 0).reshape(-1)]))
    return acc, m, l, s._replace(cat=cat, clock=clock)


def sharded_sparse_decode(cfg: KVPlaneConfig, states, q, lengths, *,
                          mode: str | None = None):
    """Vmapped-over-shards sparse decode with flash-decoding combine.

    ``states``: KVPlaneState with a leading shard axis [D, ...] (sharded
    over the data axis under pjit); q [B, H, Dh] replicated; lengths [B].
    Returns (out [B, H, Dh], states)."""
    D = states.step.shape[0]
    B, P, NP = cfg.batch, cfg.page_tokens, cfg.num_pages
    npages_global = (lengths[0] + P - 1) // P            # B=1 per long run
    shard_ids = jnp.arange(D)
    first_tokens = shard_ids * NP * P
    newest_global = jnp.maximum(npages_global - 1, 0)
    newest_local = jnp.where(newest_global // NP == shard_ids,
                             newest_global % NP, -1).astype(jnp.int32)

    acc, m, l, states = jax.vmap(
        lambda st, ft, nl: attend_sparse_partial(cfg, st, q, ft, lengths[0],
                                                 nl, mode=mode)
    )(states, first_tokens, newest_local)
    # combine: [D, B, H, *]
    m_star = m.max(axis=0, keepdims=True)
    w = jnp.exp(m - m_star)
    l_tot = (l * w).sum(axis=0)
    acc_tot = (acc * w).sum(axis=0)
    out = acc_tot / jnp.maximum(l_tot, 1e-30)
    return out.astype(q.dtype), states


# --------------------------------------------------------------------------
# memoized serve-path jit entry points (state-donating)
# --------------------------------------------------------------------------
# ``jax.jit(partial(attend_sparse, cfg))`` at every call site compiles one
# program per site AND copies the whole state (slabs included) every step —
# the serve loop holds exactly one live state, so the step donates it and
# the far-tier buffers alias through untouched.

@functools.lru_cache(maxsize=None)
def _jitted_attend_sparse(cfg: KVPlaneConfig, mode: str):
    return jax.jit(functools.partial(attend_sparse, cfg, mode=mode),
                   donate_argnums=(0,))


def jitted_attend_sparse(cfg: KVPlaneConfig, mode: str | None = None):
    return _jitted_attend_sparse(cfg, mode or cfg.fetch_mode)


def _sharded_decode_body(cfg: KVPlaneConfig, mode, states, q, lengths):
    """shard_map body of the sharded sparse decode: one shard's partial
    attention + a deterministic all_gather combine.  Identical per-shard
    math to ``sharded_sparse_decode`` (which emulates the gather as a
    stacked-array reduction), so the two are bit-equivalent."""
    s = jax.tree.map(lambda x: x[0], states)
    d = lax.axis_index("far").astype(jnp.int32)
    P, NP = cfg.page_tokens, cfg.num_pages
    npages_global = (lengths[0] + P - 1) // P
    first_token = d * NP * P
    newest_global = jnp.maximum(npages_global - 1, 0)
    newest_local = jnp.where(newest_global // NP == d,
                             newest_global % NP, -1).astype(jnp.int32)
    acc, m, l, s = attend_sparse_partial(cfg, s, q, first_token, lengths[0],
                                         newest_local, mode=mode)
    # deterministic flash-decoding combine: gather the partials in shard
    # order and reduce with the same jnp ops as the vmapped oracle
    accg = lax.all_gather(acc, "far")                    # [S, B, H, Dh]
    mg = lax.all_gather(m, "far")
    lg = lax.all_gather(l, "far")
    m_star = mg.max(axis=0, keepdims=True)
    w = jnp.exp(mg - m_star)
    l_tot = (lg * w).sum(axis=0)
    acc_tot = (accg * w).sum(axis=0)
    out = acc_tot / jnp.maximum(l_tot, 1e-30)
    return out.astype(q.dtype), jax.tree.map(lambda x: x[None], s)


@functools.lru_cache(maxsize=None)
def _jitted_sharded_decode(cfg: KVPlaneConfig, mode: str, mesh):
    if mesh is None:
        return jax.jit(functools.partial(sharded_sparse_decode, cfg,
                                         mode=mode),
                       donate_argnums=(0,))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    sp = jax.tree.map(lambda _: P("far"),
                      jax.eval_shape(functools.partial(init, cfg)))
    fn = shard_map(functools.partial(_sharded_decode_body, cfg, mode),
                   mesh=mesh, in_specs=(sp, P(), P()),
                   out_specs=(P(), sp), check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))


def jitted_sharded_decode(cfg: KVPlaneConfig, mode: str | None = None,
                          mesh=None):
    """Sharded sparse decode entry: ``mesh=None`` is the vmapped
    single-device oracle; a ``far`` mesh (``launch.mesh.make_far_mesh``)
    runs each plane shard on its own device via shard_map, attending its
    slab partition locally and combining with an all_gather — the shared
    sharded pool of the serving deployment."""
    return _jitted_sharded_decode(cfg, mode or cfg.fetch_mode, mesh)


def append_sharded(cfg: KVPlaneConfig, states, k_new, v_new, lengths):
    """Append one token's KV (B=1) into the owning shard's slab page (+ the
    frame copy if resident) and refresh that page's summaries.

    Egress faults (DESIGN.md §6c) gate the whole append atomically: when
    the owning shard's remote write of page ``gpage`` faults at token tick
    ``t``, ownership is masked off and NOTHING mutates — no slab row, no
    kmax/kmin summary, no frame write-through — so the page summaries
    never describe half-appended tokens."""
    D = states.step.shape[0]
    P, NP = cfg.page_tokens, cfg.num_pages
    t = lengths[0]
    gpage = t // P
    slot = t % P
    shard_ids = jnp.arange(D)
    own = gpage // NP == shard_ids
    fc = cfg.faults
    if fc is not None and fc.egress_active:
        own = own & ~fc.egress_fail(t, jnp.broadcast_to(gpage, (D,)),
                                    shard_ids)
    lpage = (gpage % NP).astype(jnp.int32)

    def per_shard(st, is_owner):
        kn = k_new[0].astype(cfg.dtype)                  # [KVH, Dh]
        vn = v_new[0].astype(cfg.dtype)
        gp = 0 * NP + lpage                              # b = 0
        ks = st.k_slab.at[:, gp, slot].set(
            jnp.where(is_owner, kn, st.k_slab[:, gp, slot]))
        vs = st.v_slab.at[:, gp, slot].set(
            jnp.where(is_owner, vn, st.v_slab[:, gp, slot]))
        kmax = st.kmax.at[:, gp].set(
            jnp.where(is_owner,
                      jnp.maximum(st.kmax[:, gp], kn.astype(jnp.float32)),
                      st.kmax[:, gp]))
        kmin = st.kmin.at[:, gp].set(
            jnp.where(is_owner,
                      jnp.minimum(st.kmin[:, gp], kn.astype(jnp.float32)),
                      st.kmin[:, gp]))
        # write-through to the frame copy if the page is resident
        f = st.page_table[0, lpage]
        safe_f = jnp.maximum(f, 0)
        do_frame = jnp.logical_and(is_owner, f >= 0)
        kf = st.k_frames.at[:, safe_f, slot].set(
            jnp.where(do_frame, kn, st.k_frames[:, safe_f, slot]))
        vf = st.v_frames.at[:, safe_f, slot].set(
            jnp.where(do_frame, vn, st.v_frames[:, safe_f, slot]))
        rows = st.page_rows.at[0, lpage].set(
            jnp.where(do_frame, jnp.maximum(st.page_rows[0, lpage], slot + 1),
                      st.page_rows[0, lpage]))
        return st._replace(k_slab=ks, v_slab=vs, kmax=kmax, kmin=kmin,
                           k_frames=kf, v_frames=vf, page_rows=rows)

    return jax.vmap(per_shard)(states, own)
