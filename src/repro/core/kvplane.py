"""Production tiered KV cache on the Atlas plane (the serve-path fast path).

Two modes, matching the two ends of the paper's spectrum:

* **dense paging mode** (decode_32k): the whole cache fits local; KV lives
  in a paged frame pool indirected by a page table (vLLM-style).  Dense
  decode attention touches every token -> every card bit sets -> CAR = 1 ->
  all pages stay on the paging path.  The always-on CAT profiling still
  runs (its cost is part of what we benchmark).

* **sparse hybrid mode** (long_500k): frames hold only a hot subset of
  pages; the rest live in the far tier (slab).  Each step:
    1. page summaries (kmax/kmin) are scored against q *without fetching*
       (offload-space computation, `kernels.topk_pages`);
    2. the top-k pages are ensured local with a *static fetch budget*:
       PSF=paging pages arrive whole (bulk DMA), PSF=runtime pages arrive
       as a row-gather of their CAT-marked hot rows only;
    3. paged flash attention runs over the local pool;
    4. CAT bits are set for the attended rows, eviction victims are chosen
       page-granularly by clock, and their PSF is recomputed from CAR.

Everything is static-shaped and vectorized: this is the form of the hybrid
plane that lowers into the multi-pod dry-run.  The fully dynamic
(fault-driven) plane lives in ``repro.core.plane`` and backs the
benchmarks; both implement the same policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class KVPlaneConfig:
    kv_heads: int
    head_dim: int
    page_tokens: int          # P: tokens per page
    num_pages: int            # NP: logical pages (covers max seq len)
    num_frames: int           # F: local frame pool (== B*NP in dense mode)
    batch: int                # sequences served per shard
    sparse_topk: int = 0      # 0 = dense paging mode; >0 = hybrid sparse
    fetch_budget: int = 8     # pages ensured local per step (sparse mode)
    car_threshold: float = 0.8
    dtype: object = jnp.bfloat16

    @property
    def dense(self) -> bool:
        return self.sparse_topk == 0


class KVPlaneState(NamedTuple):
    """Per-layer state (callers stack a leading layer axis and scan)."""
    k_frames: jnp.ndarray   # [KVH, F, P, Dh]
    v_frames: jnp.ndarray   # [KVH, F, P, Dh]
    page_table: jnp.ndarray # [B, NP] int32: logical page -> frame (-1 far)
    # --- far tier + profiling (sparse mode; size-1 placeholders in dense)
    k_slab: jnp.ndarray     # [KVH, B*NP, P, Dh]
    v_slab: jnp.ndarray     # [KVH, B*NP, P, Dh]
    kmax: jnp.ndarray       # [KVH, B*NP, Dh] page summaries (always local)
    kmin: jnp.ndarray       # [KVH, B*NP, Dh]
    cat: jnp.ndarray        # [B, NP, P] bool
    psf: jnp.ndarray        # [B, NP] bool
    hot_hint: jnp.ndarray   # [B, NP, P] bool: CAT snapshot from last residency
    page_rows: jnp.ndarray  # [B, NP] int32: valid rows in the frame copy
    frame_page: jnp.ndarray # [F] int32: frame -> b*NP+page (-1 free)
    clock: jnp.ndarray      # [F] int32
    step: jnp.ndarray       # [] int32


def init(cfg: KVPlaneConfig) -> KVPlaneState:
    KVH, F, P, Dh, B, NP = (cfg.kv_heads, cfg.num_frames, cfg.page_tokens,
                            cfg.head_dim, cfg.batch, cfg.num_pages)
    dense = cfg.dense
    slab_pages = 1 if dense else B * NP
    if dense:
        # fully resident: page (b, j) -> frame b*NP + j
        pt = (jnp.arange(B)[:, None] * NP + jnp.arange(NP)[None, :]).astype(
            jnp.int32)
        frame_page = jnp.arange(B * NP, dtype=jnp.int32)
        assert F == B * NP, "dense mode: frames must cover the cache"
    else:
        pt = jnp.full((B, NP), -1, jnp.int32)
        frame_page = jnp.full((F,), -1, jnp.int32)
    return KVPlaneState(
        k_frames=jnp.zeros((KVH, F, P, Dh), cfg.dtype),
        v_frames=jnp.zeros((KVH, F, P, Dh), cfg.dtype),
        page_table=pt,
        k_slab=jnp.zeros((KVH, slab_pages, P, Dh), cfg.dtype),
        v_slab=jnp.zeros((KVH, slab_pages, P, Dh), cfg.dtype),
        kmax=jnp.full((KVH, slab_pages, Dh), -jnp.inf, jnp.float32),
        kmin=jnp.full((KVH, slab_pages, Dh), jnp.inf, jnp.float32),
        cat=jnp.zeros((B, NP, P), bool),
        psf=jnp.ones((B, NP), bool),
        hot_hint=jnp.zeros((B, NP, P), bool),
        page_rows=jnp.zeros((B, NP), jnp.int32),
        frame_page=frame_page,
        clock=jnp.zeros((F,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


# --------------------------------------------------------------------------
# dense paging mode
# --------------------------------------------------------------------------

def append_dense(cfg: KVPlaneConfig, s: KVPlaneState, k_new, v_new, lengths):
    """Write one new token per sequence.  k/v_new: [B, KVH, Dh];
    lengths: [B] current lengths (token goes at index lengths[b])."""
    B, P = cfg.batch, cfg.page_tokens
    page = lengths // P
    slot = lengths % P
    frame = s.page_table[jnp.arange(B), page]            # [B]
    kf = s.k_frames.at[:, frame, slot].set(
        k_new.transpose(1, 0, 2).astype(cfg.dtype))
    vf = s.v_frames.at[:, frame, slot].set(
        v_new.transpose(1, 0, 2).astype(cfg.dtype))
    return s._replace(k_frames=kf, v_frames=vf, step=s.step + 1)


def attend_dense(cfg: KVPlaneConfig, s: KVPlaneState, q, lengths):
    """q: [B, H, Dh] -> [B, H, Dh] via paged attention over the frame pool.
    Also runs the always-on CAT profiling (dense touch -> CAR -> 1)."""
    P, NP = cfg.page_tokens, cfg.num_pages
    page_lens = ops.lengths_to_page_lens(lengths, NP, P)
    out, _used = ops.paged_attention(q, s.k_frames, s.v_frames, s.page_table,
                                     page_lens)
    # profiling: dense attention reads every position below length — the
    # program touched every card (CAR -> 1, pages stay on the paging path)
    pos = (jnp.arange(NP * P)).reshape(NP, P)
    touched = pos[None] < lengths[:, None, None]          # [B, NP, P]
    s = s._replace(cat=jnp.logical_or(s.cat, touched),
                   page_rows=page_lens,
                   clock=jnp.full_like(s.clock, s.step))
    return out, s


# --------------------------------------------------------------------------
# sparse hybrid mode (the Atlas showcase)
# --------------------------------------------------------------------------

def write_page_to_slab(cfg: KVPlaneConfig, s: KVPlaneState, b: int,
                       page_idx, k_page, v_page):
    """Prefill helper: place a full page [KVH, P, Dh] in the far tier and
    update its summaries."""
    gp = b * cfg.num_pages + page_idx
    ks = lax.dynamic_update_index_in_dim(s.k_slab, k_page, gp, axis=1)
    vs = lax.dynamic_update_index_in_dim(s.v_slab, v_page, gp, axis=1)
    kmax = s.kmax.at[:, gp].set(k_page.max(axis=1).astype(jnp.float32))
    kmin = s.kmin.at[:, gp].set(k_page.min(axis=1).astype(jnp.float32))
    return s._replace(k_slab=ks, v_slab=vs, kmax=kmax, kmin=kmin)


def _evict_and_fetch(cfg: KVPlaneConfig, s: KVPlaneState, b,
                     want_pages: jnp.ndarray, page_fill: jnp.ndarray):
    """Ensure up to ``fetch_budget`` of ``want_pages`` (logical ids for
    sequence ``b``) are local.  Vectorized: victims = coldest unpinned
    frames; fetched via paging (whole page) or runtime (CAT-marked rows)
    per the page's PSF.  ``page_fill`` [NP]: appended tokens per page
    (bounds the valid rows of paging fetches).  Returns updated state."""
    P, NP, F, KVH, Dh = (cfg.page_tokens, cfg.num_pages, cfg.num_frames,
                         cfg.kv_heads, cfg.head_dim)
    K = want_pages.shape[0]

    resident = s.page_table[b, want_pages] >= 0
    missing = jnp.logical_and(~resident, want_pages >= 0)
    # take the first `fetch_budget` missing pages (stable order by score rank)
    order = jnp.argsort(~missing)                # missing first
    fetch = jnp.where(jnp.arange(K) < cfg.fetch_budget,
                      want_pages[order], -1)[:cfg.fetch_budget]
    fetch = jnp.where(missing[order][:cfg.fetch_budget], fetch, -1)

    # victims: coldest frames, excluding wanted-resident pages (pin analogue)
    want_frames = jnp.where(resident, s.page_table[b, want_pages], -1)
    pinned = jnp.zeros((F,), bool).at[jnp.maximum(want_frames, 0)].set(
        want_frames >= 0)
    score = jnp.where(pinned, jnp.iinfo(jnp.int32).max, s.clock)
    _, victims = lax.top_k(-score, cfg.fetch_budget)     # [budget]

    def fetch_one(i, s):
        pg = fetch[i]
        f = victims[i]

        def do(s):
            # ---- page-out the victim (egress is always page-granular) ----
            old_gp = s.frame_page[f]
            old_b, old_pg = old_gp // NP, old_gp % NP

            def evict(s):
                # KV pages are append-only and appends write through to the
                # slab, so frames are never dirty: page-out is metadata-only
                # (no writeback — and packed runtime frames must not
                # overwrite the canonical slab layout).
                # PSF recomputed from CAR at page-out (the Atlas policy).
                # Denominator is the FULL page: CAR asks "would fetching the
                # whole page have been worth it?"  A packed runtime page has
                # at most n_hot marked cards -> CAR = n_hot/P stays below
                # threshold -> the page keeps taking the runtime path.
                cat_now = s.cat[old_b, old_pg]
                car = jnp.mean(cat_now.astype(jnp.float32))
                # snapshot the hot set for the next runtime fetch.  For a
                # packed page, card bits refer to packed slots: map them
                # back through the previous hint (packed slot i == i-th set
                # bit of the old hint, by stable sort).
                old_hint = s.hot_hint[old_b, old_pg]
                rank = jnp.cumsum(old_hint.astype(jnp.int32)) - 1
                packed_back = jnp.logical_and(
                    old_hint, cat_now[jnp.clip(rank, 0, P - 1)])
                was_full = s.page_rows[old_b, old_pg] >= P
                hint = jnp.where(was_full, cat_now, packed_back)
                return s._replace(
                    psf=s.psf.at[old_b, old_pg].set(car >= cfg.car_threshold),
                    hot_hint=s.hot_hint.at[old_b, old_pg].set(hint),
                    cat=s.cat.at[old_b, old_pg].set(False),
                    page_rows=s.page_rows.at[old_b, old_pg].set(0),
                    page_table=s.page_table.at[old_b, old_pg].set(-1))

            s = lax.cond(old_gp >= 0, evict, lambda s: s, s)

            # ---- ingress per PSF --------------------------------------
            gp = b * NP + pg
            kpage = lax.dynamic_index_in_dim(s.k_slab, gp, 1, keepdims=False)
            vpage = lax.dynamic_index_in_dim(s.v_slab, gp, 1, keepdims=False)
            hot = s.hot_hint[b, pg]                      # [P] runtime-path rows
            n_hot = jnp.sum(hot.astype(jnp.int32))
            # first-touch / append pages always take paging; else the PSF
            take_paging = jnp.logical_or(s.psf[b, pg], n_hot == 0)
            # runtime path: pack only the CAT-marked rows to the front of
            # the frame (object fetching moves hot objects into contiguous
            # local space — decode attention is KV-permutation-invariant)
            perm = jnp.argsort(~hot)                     # hot rows first
            kpk = jnp.take(kpage, perm, axis=1)
            vpk = jnp.take(vpage, perm, axis=1)
            kpage = jnp.where(take_paging, kpage, kpk)
            vpage = jnp.where(take_paging, vpage, vpk)
            rows = jnp.where(take_paging, page_fill[pg], n_hot).astype(jnp.int32)
            kf = lax.dynamic_update_index_in_dim(s.k_frames, kpage, f, 1)
            vf = lax.dynamic_update_index_in_dim(s.v_frames, vpage, f, 1)
            return s._replace(
                k_frames=kf, v_frames=vf,
                page_table=s.page_table.at[b, pg].set(f),
                page_rows=s.page_rows.at[b, pg].set(rows),
                frame_page=s.frame_page.at[f].set(gp),
                # CAT cleared at page-in ("accessed since last swapped in");
                # the profiling step marks attended rows afterwards
                cat=s.cat.at[b, pg].set(False),
                clock=s.clock.at[f].set(s.step))

        return lax.cond(pg >= 0, do, lambda s: s, s)

    return lax.fori_loop(0, cfg.fetch_budget, fetch_one, s)


def attend_sparse(cfg: KVPlaneConfig, s: KVPlaneState, q, lengths):
    """Hybrid sparse decode.  q: [B, H, Dh] (B = 1 per shard in long_500k).

    Returns (out [B, H, Dh], state)."""
    B, P, NP = cfg.batch, cfg.page_tokens, cfg.num_pages
    K = cfg.sparse_topk
    s = s._replace(step=s.step + 1)

    # 1. offload-space scoring against far-resident summaries
    scores = ops.page_scores(q, s.kmax.reshape(cfg.kv_heads, -1, cfg.head_dim),
                             s.kmin.reshape(cfg.kv_heads, -1, cfg.head_dim))
    # scores: [B, KVH, B*NP] -> per-sequence slice, reduce over kv heads
    per_page = scores.max(axis=1)                        # [B, B*NP]

    def seq_sel(b):
        sl = lax.dynamic_slice_in_dim(per_page[b], b * NP, NP)
        npages = jnp.maximum((lengths[b] + P - 1) // P, 1)
        valid = jnp.arange(NP) < npages
        sl = jnp.where(valid, sl, -jnp.inf)
        _, top = lax.top_k(sl, K)
        top = jnp.where(jnp.arange(K) < jnp.minimum(npages, K), top, -1)
        # always include the newest page (it is being appended)
        newest = npages - 1
        present = jnp.any(top == newest)
        top = top.at[K - 1].set(jnp.where(present, top[K - 1], newest))
        return top

    tops = jax.vmap(seq_sel)(jnp.arange(B))              # [B, K]

    # 2. ensure-local with static fetch budget (ingress via PSF)
    fills = ops.lengths_to_page_lens(lengths, NP, P)      # [B, NP]

    def per_seq(b, s):
        return _evict_and_fetch(cfg, s, b, tops[b], fills[b])
    s = lax.fori_loop(0, B, per_seq, s)

    # 3. attention over the selected local pages only (columns = selection;
    #    per-column row counts come from page_rows — packed pages included)
    bidx = jnp.arange(B)[:, None]
    sel_frames = s.page_table[bidx, tops]                # [B, K] (-1 if miss)
    sel_valid = sel_frames >= 0
    sel_rows = jnp.where(sel_valid, s.page_rows[bidx, tops], 0)
    out, used = ops.paged_attention(
        q, s.k_frames, s.v_frames,
        jnp.where(sel_valid, sel_frames, -1), sel_rows)

    # 4. always-on profiling: mark the cards of rows whose attention weight
    #    was above the within-page mean (``used`` from the attention kernel)
    #    — flat pages mark everything -> CAR high -> paging; skewed pages
    #    mark the few heavy rows -> CAR low -> runtime
    touched_pages = jnp.where(sel_valid, tops, 0)
    cat = s.cat.at[bidx, touched_pages].set(
        jnp.where(sel_valid[..., None],
                  jnp.logical_or(s.cat[bidx, touched_pages], used),
                  s.cat[bidx, touched_pages]))
    clock = s.clock.at[jnp.maximum(sel_frames, 0).reshape(-1)].set(
        jnp.where(sel_valid.reshape(-1), s.step,
                  s.clock[jnp.maximum(sel_frames, 0).reshape(-1)]))
    return out, s._replace(cat=cat, clock=clock)


# --------------------------------------------------------------------------
# window (ring-buffer) mode: sliding-window attention at long context
# --------------------------------------------------------------------------

def append_window(cfg: KVPlaneConfig, s: KVPlaneState, k_new, v_new, lengths):
    """Ring-buffer append for SWA (mixtral long_500k): the cache covers only
    the window; new tokens overwrite the oldest slot."""
    W = cfg.num_pages * cfg.page_tokens
    return append_dense(cfg, s, k_new, v_new, lengths % W)


def attend_window(cfg: KVPlaneConfig, s: KVPlaneState, q, lengths):
    """Attention over the ring buffer: every resident slot is inside the
    window by construction (older tokens were overwritten)."""
    W = cfg.num_pages * cfg.page_tokens
    return attend_dense(cfg, s, q, jnp.minimum(lengths, W))


# --------------------------------------------------------------------------
# sharded sparse decode: plane shards own disjoint page ranges; partial
# attention per shard, log-sum-exp combine across shards (flash-decoding)
# --------------------------------------------------------------------------

def _attend_pages_partial(q, k_frames, v_frames, table, rows):
    """Unnormalized attention over selected local pages.

    q [B, H, Dh]; k/v_frames [KVH, F, P, Dh]; table/rows [B, K].
    Returns (acc [B, H, Dh] f32, m [B, H, 1], l [B, H, 1],
             used [B, K, P] bool)."""
    B, H, Dh = q.shape
    KVH, F, P, _ = k_frames.shape
    K = table.shape[1]
    G = H // KVH

    def per_seq(qb, pt, pr):
        safe = jnp.maximum(pt, 0)
        k = k_frames[:, safe].reshape(KVH, K * P, Dh)
        v = v_frames[:, safe].reshape(KVH, K * P, Dh)
        qg = qb.reshape(KVH, G, Dh).astype(jnp.float32)
        sc = jnp.einsum("kgd,ksd->kgs", qg, k.astype(jnp.float32))
        sc *= 1.0 / jnp.sqrt(jnp.float32(Dh))
        row = jnp.tile(jnp.arange(P), K)
        valid = (row < jnp.repeat(pr, P)) & jnp.repeat(pt >= 0, P)
        sc = jnp.where(valid[None, None, :], sc, NEG_INF)
        m = sc.max(-1, keepdims=True)                    # [KVH, G, 1]
        p = jnp.exp(sc - m)
        p = jnp.where(valid[None, None, :], p, 0.0)
        l = p.sum(-1, keepdims=True)
        acc = jnp.einsum("kgs,ksd->kgd", p, v.astype(jnp.float32))
        # card signal: weight above within-page mean
        pp = p.reshape(KVH, G, K, P)
        mass = pp.sum(-1, keepdims=True)
        used = (pp * P > mass).any(axis=(0, 1)) & valid.reshape(K, P)
        return (acc.reshape(H, Dh), m.reshape(H, 1), l.reshape(H, 1), used)

    return jax.vmap(per_seq)(q, table, rows)


def attend_sparse_partial(cfg: KVPlaneConfig, s: KVPlaneState, q,
                          first_token, global_len, newest_page):
    """One shard's contribution to sharded sparse decode.

    ``first_token``: absolute position of this shard's first page;
    ``global_len``: sequence length; ``newest_page``: local index of the
    append page (-1 if another shard owns it).  Returns (acc, m, l, s)."""
    B, P, NP = cfg.batch, cfg.page_tokens, cfg.num_pages
    K = cfg.sparse_topk
    s = s._replace(step=s.step + 1)
    page_fill = jnp.clip(global_len - first_token - jnp.arange(NP) * P, 0, P
                         ).astype(jnp.int32)
    n_valid_pages = jnp.sum((page_fill > 0).astype(jnp.int32))

    scores = ops.page_scores(q, s.kmax.reshape(cfg.kv_heads, -1, cfg.head_dim),
                             s.kmin.reshape(cfg.kv_heads, -1, cfg.head_dim))
    per_page = scores.max(axis=1)                        # [B, B*NP]

    def seq_sel(b):
        sl = lax.dynamic_slice_in_dim(per_page[b], b * NP, NP)
        valid = jnp.arange(NP) < n_valid_pages
        sl = jnp.where(valid, sl, -jnp.inf)
        _, top = lax.top_k(sl, K)
        top = jnp.where(jnp.arange(K) < jnp.minimum(n_valid_pages, K),
                        top, -1)
        # the append page must stay selected on its owner shard; if the
        # scorer didn't pick it, it replaces the lowest-score selection
        present = jnp.logical_or(jnp.any(top == newest_page),
                                 newest_page < 0)
        top = top.at[K - 1].set(jnp.where(present, top[K - 1], newest_page))
        return top

    tops = jax.vmap(seq_sel)(jnp.arange(B))              # [B, K]

    def per_seq(b, s):
        return _evict_and_fetch(cfg, s, b, tops[b], page_fill)
    s = lax.fori_loop(0, B, per_seq, s)

    bidx = jnp.arange(B)[:, None]
    safe_tops = jnp.maximum(tops, 0)
    sel_frames = jnp.where(tops >= 0, s.page_table[bidx, safe_tops], -1)
    sel_valid = sel_frames >= 0
    sel_rows = jnp.where(sel_valid, s.page_rows[bidx, safe_tops], 0)
    acc, m, l, used = _attend_pages_partial(
        q, s.k_frames, s.v_frames,
        jnp.where(sel_valid, sel_frames, -1), sel_rows)

    touched = jnp.where(sel_valid, tops, 0)
    cat = s.cat.at[bidx, touched].set(
        jnp.where(sel_valid[..., None],
                  jnp.logical_or(s.cat[bidx, touched], used),
                  s.cat[bidx, touched]))
    clock = s.clock.at[jnp.maximum(sel_frames, 0).reshape(-1)].set(
        jnp.where(sel_valid.reshape(-1), s.step,
                  s.clock[jnp.maximum(sel_frames, 0).reshape(-1)]))
    return acc, m, l, s._replace(cat=cat, clock=clock)


def sharded_sparse_decode(cfg: KVPlaneConfig, states, q, lengths):
    """Vmapped-over-shards sparse decode with flash-decoding combine.

    ``states``: KVPlaneState with a leading shard axis [D, ...] (sharded
    over the data axis under pjit); q [B, H, Dh] replicated; lengths [B].
    Returns (out [B, H, Dh], states)."""
    D = states.step.shape[0]
    B, P, NP = cfg.batch, cfg.page_tokens, cfg.num_pages
    npages_global = (lengths[0] + P - 1) // P            # B=1 per long run
    shard_ids = jnp.arange(D)
    first_tokens = shard_ids * NP * P
    newest_global = jnp.maximum(npages_global - 1, 0)
    newest_local = jnp.where(newest_global // NP == shard_ids,
                             newest_global % NP, -1).astype(jnp.int32)

    acc, m, l, states = jax.vmap(
        lambda st, ft, nl: attend_sparse_partial(cfg, st, q, ft, lengths[0], nl)
    )(states, first_tokens, newest_local)
    # combine: [D, B, H, *]
    m_star = m.max(axis=0, keepdims=True)
    w = jnp.exp(m - m_star)
    l_tot = (l * w).sum(axis=0)
    acc_tot = (acc * w).sum(axis=0)
    out = acc_tot / jnp.maximum(l_tot, 1e-30)
    return out.astype(q.dtype), states


def append_sharded(cfg: KVPlaneConfig, states, k_new, v_new, lengths):
    """Append one token's KV (B=1) into the owning shard's slab page (+ the
    frame copy if resident) and refresh that page's summaries."""
    D = states.step.shape[0]
    P, NP = cfg.page_tokens, cfg.num_pages
    t = lengths[0]
    gpage = t // P
    slot = t % P
    shard_ids = jnp.arange(D)
    own = gpage // NP == shard_ids
    lpage = (gpage % NP).astype(jnp.int32)

    def per_shard(st, is_owner):
        kn = k_new[0].astype(cfg.dtype)                  # [KVH, Dh]
        vn = v_new[0].astype(cfg.dtype)
        gp = 0 * NP + lpage                              # b = 0
        ks = st.k_slab.at[:, gp, slot].set(
            jnp.where(is_owner, kn, st.k_slab[:, gp, slot]))
        vs = st.v_slab.at[:, gp, slot].set(
            jnp.where(is_owner, vn, st.v_slab[:, gp, slot]))
        kmax = st.kmax.at[:, gp].set(
            jnp.where(is_owner,
                      jnp.maximum(st.kmax[:, gp], kn.astype(jnp.float32)),
                      st.kmax[:, gp]))
        kmin = st.kmin.at[:, gp].set(
            jnp.where(is_owner,
                      jnp.minimum(st.kmin[:, gp], kn.astype(jnp.float32)),
                      st.kmin[:, gp]))
        # write-through to the frame copy if the page is resident
        f = st.page_table[0, lpage]
        safe_f = jnp.maximum(f, 0)
        do_frame = jnp.logical_and(is_owner, f >= 0)
        kf = st.k_frames.at[:, safe_f, slot].set(
            jnp.where(do_frame, kn, st.k_frames[:, safe_f, slot]))
        vf = st.v_frames.at[:, safe_f, slot].set(
            jnp.where(do_frame, vn, st.v_frames[:, safe_f, slot]))
        rows = st.page_rows.at[0, lpage].set(
            jnp.where(do_frame, jnp.maximum(st.page_rows[0, lpage], slot + 1),
                      st.page_rows[0, lpage]))
        return st._replace(k_slab=ks, v_slab=vs, kmax=kmax, kmin=kmin,
                           k_frames=kf, v_frames=vf, page_rows=rows)

    return jax.vmap(per_shard)(states, own)
