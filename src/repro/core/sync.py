"""Synchronization protocol between the two paths (paper §4.2).

JAX's functional semantics give us Invariant #1 for free (PSF is only
written inside ``page_out``; a state value is never observed mid-mutation).
The remaining invariants are realized with the per-page deref counts
(``PlaneState.pin``):

* Invariant #2 (object-in vs page-out): ``paths._victim_frame`` masks pinned
  pages out of victim selection.  Within one batch the plan-then-execute
  engine (``repro.core.batch``) additionally refreshes the page clock of
  every target page up front, so mid-batch eviction prefers non-target
  pages (a soft pin); should a target still be paged out under extreme
  pressure, the final gather serves its written-back slab copy.
* Invariant #3 (deref scope vs evacuation): ``plane.evacuate`` skips pinned
  pages, and pins the source page while compacting it.

This module provides the batched pin helpers used by host-side runtimes
(the serving engine holds pins *across* scheduler ticks while requests are
in flight) plus the live-lock guard from §4.2: if too much data is pinned,
the runtime forces pages onto the paging path so they can be swapped out
and later paged back in without pointer updates.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import state as st
from .layout import LOCAL, PlaneConfig


def pin_objects(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray
                ) -> st.PlaneState:
    """Open a dereference scope for each object (duplicates accumulate)."""
    v = s.obj_loc[obj_ids] // cfg.page_objs
    return s._replace(pin=s.pin.at[v].add(1))


def unpin_objects(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray
                  ) -> st.PlaneState:
    """Close the scopes opened by :func:`pin_objects`."""
    v = s.obj_loc[obj_ids] // cfg.page_objs
    return s._replace(pin=s.pin.at[v].add(-1))


def pinned_fraction(cfg: PlaneConfig, s: st.PlaneState) -> jnp.ndarray:
    """Fraction of local frames whose page is pinned (live-lock monitor)."""
    v = s.vpage_of
    pinned = jnp.where(v >= 0, s.pin[jnp.maximum(v, 0)] > 0, False)
    return jnp.mean(pinned.astype(jnp.float32))


def force_paging_under_pressure(cfg: PlaneConfig, s: st.PlaneState,
                                threshold: float = 0.75) -> st.PlaneState:
    """Paper §4.2 live-lock mitigation: under memory pressure, flip the PSF
    of pinned local pages to ``paging`` so that — once their scopes close —
    they can be swapped out and re-fetched without pointer updates."""
    pressure = pinned_fraction(cfg, s) >= threshold
    pinned_local = (s.backing == LOCAL) & (s.pin > 0)
    new_psf = jnp.where(jnp.logical_and(pressure, pinned_local), True, s.psf)
    return s._replace(psf=new_psf)
