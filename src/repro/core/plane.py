"""The Atlas hybrid data plane: batched access, evacuation, writeback.

``access`` is the batched read barrier (paper Algorithm 1/2): for each
requested object it

  1. increments the deref count of the object's page (pre-scope barrier;
     Invariant #2: pinned pages are never chosen as page-out victims),
  2. on a miss consults the page's PSF and takes either the **paging** path
     (whole-page fetch, vaddrs stable) or the **runtime** path (object moved
     to the ingress fill page, smart pointer rewritten),
  3. records the access in the CAT (card bit), the per-object access bit and
     the page clock (always-on profiling),
  4. after the batch, gathers all rows (now guaranteed local) and releases
     the deref counts (post-scope barrier).

Eviction happens only page-granularly inside ``alloc_frame`` (egress path,
paper §4.1) — the PSF of the victim is recomputed from its CAR there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import paths
from . import state as st
from .layout import FREE, LOCAL, REMOTE, PlaneConfig


# --------------------------------------------------------------------------
# batched access (the hybrid ingress)
# --------------------------------------------------------------------------

def _ensure_local_one(cfg: PlaneConfig, s: st.PlaneState, o) -> st.PlaneState:
    """Fault in object ``o`` if needed, pin its (final) page, record access."""
    vaddr = s.obj_loc[o]
    v = vaddr // cfg.page_objs
    is_local = s.backing[v] == LOCAL

    def miss(s):
        s = s._replace(stats=st.bump(s.stats, misses=1))
        return lax.cond(
            s.psf[v],
            lambda s: paths.page_in_with_readahead(cfg, s, v),
            lambda s: paths.object_in(cfg, s, o),
            s)

    s = lax.cond(is_local,
                 lambda s: s._replace(stats=st.bump(s.stats, hits=1)),
                 miss, s)

    # the object may have moved (runtime path): re-read the smart pointer
    vaddr2 = s.obj_loc[o]
    v2, slot2 = vaddr2 // cfg.page_objs, vaddr2 % cfg.page_objs
    s = paths.pin_page(s, v2)                       # pre-scope barrier
    s = paths.touch(cfg, s, v2, slot2, obj_id=o)    # CAT + access bit + clock
    return s


def access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray):
    """Batched hybrid access.  Returns ``(state, rows[R, D])``.

    Atlas uses *fine-grained* dereference scopes — one per smart-pointer
    dereference (§4.2) — so each request pins its page only between fault-in
    and the raw read, then releases it.  At most a handful of pages are
    pinned at any time (current page + fill cursors), which is the paper's
    live-lock bound."""
    R = obj_ids.shape[0]
    s = s._replace(step=s.step + 1)
    out = jnp.zeros((R, cfg.obj_dim), cfg.dtype)

    def body(i, carry):
        s, out = carry
        o = obj_ids[i]
        s = _ensure_local_one(cfg, s, o)          # ends with the page pinned
        vaddr = s.obj_loc[o]
        v, slot = vaddr // cfg.page_objs, vaddr % cfg.page_objs
        row = s.frames[s.frame_of[v], slot]       # raw-pointer use
        out = lax.dynamic_update_index_in_dim(out, row, i, axis=0)
        s = paths.unpin_page(s, v)                # post-scope barrier
        return s, out

    s, out = lax.fori_loop(0, R, body, (s, out))
    return s, out


def update(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
           rows: jnp.ndarray):
    """Batched write-through-local: fault in, overwrite rows, mark dirty."""
    R = obj_ids.shape[0]
    s = s._replace(step=s.step + 1)
    rows = rows.astype(cfg.dtype)

    def body(i, s):
        o = obj_ids[i]
        s = _ensure_local_one(cfg, s, o)
        vaddr = s.obj_loc[o]
        v, slot = vaddr // cfg.page_objs, vaddr % cfg.page_objs
        s = s._replace(frames=s.frames.at[s.frame_of[v], slot].set(rows[i]),
                       dirty=s.dirty.at[v].set(True))
        return paths.unpin_page(s, v)

    return lax.fori_loop(0, R, body, s)


# --------------------------------------------------------------------------
# evacuation (concurrent compactor analogue, paper §4.3)
# --------------------------------------------------------------------------

def evacuate(cfg: PlaneConfig, s: st.PlaneState,
             garbage_threshold: float | None = None,
             max_pages: int = 16) -> st.PlaneState:
    """Compact local pages whose dead-slot ratio exceeds the threshold.

    Live objects are segregated by their access bit: recently-accessed
    ("hot") objects are appended to a dedicated hot destination page,
    the rest to a cold one — manufacturing the spatial locality that lets
    subsequent accesses take the cheap paging path.  All access bits are
    cleared at the end (paper: "cleared by the evacuator at the end of each
    evacuation").

    Evacuation is *incremental*: at most ``max_pages`` victims (the highest
    garbage ratios) are compacted per call, bounding the pause the
    concurrent evacuator imposes on the application — exactly the
    tail-latency discipline the paper demands of memory management."""
    thr = cfg.evac_garbage_threshold if garbage_threshold is None else garbage_threshold
    P = cfg.page_objs

    # victim selection: top-K local unpinned pages by garbage ratio
    allocated_all = s.alloc_count
    dead_all = allocated_all - s.live_count
    ratio_all = dead_all.astype(jnp.float32) / jnp.maximum(allocated_all, 1)
    eligible = ((s.backing == LOCAL) & (s.pin == 0) & (allocated_all > 0)
                & (ratio_all > thr))
    score = jnp.where(eligible, ratio_all, -1.0)
    k = min(max_pages, cfg.num_vpages)
    _, victims = lax.top_k(score, k)
    victim_ok = score[victims] > -1.0

    def page_body(i, s):
        v = victims[i]
        # re-check eligibility against the *current* state (earlier moves
        # may have drained or freed this page)
        allocated = s.alloc_count[v]
        dead = allocated - s.live_count[v]
        garbage_ratio = dead.astype(jnp.float32) / jnp.maximum(allocated, 1)
        selected = (
            victim_ok[i]
            & (s.backing[v] == LOCAL)
            & (s.pin[v] == 0)
            & (allocated > 0)
            & (garbage_ratio > thr)
        )

        def evacuate_page(s):
            # pin the source so destination allocation can't page it out
            # from under the compactor (Invariant #3 mechanism)
            s = paths.pin_page(s, v)

            def slot_body(p, s):
                o = s.obj_of[v, p]

                def move(s):
                    row = s.frames[s.frame_of[v], p]
                    hot = s.access[v, p]
                    was_carded = s.cat[v, p]
                    s, v_new, slot_new = lax.cond(
                        hot,
                        lambda s: paths._append_obj(cfg, s, o, row, "evac_hot_vpage"),
                        lambda s: paths._append_obj(cfg, s, o, row, "evac_cold_vpage"),
                        s)
                    # the evacuator preserves card bits across the move (§4.3)
                    s = s._replace(
                        cat=s.cat.at[v_new, slot_new].set(was_carded),
                        access=s.access.at[v_new, slot_new].set(hot),
                        stats=st.bump(s.stats, evac_moved=1))
                    return s

                return lax.cond(o >= 0, move, lambda s: s, s)

            s = lax.fori_loop(0, P, slot_body, s)
            s = paths.unpin_page(s, v)
            # the pin kept _kill_old_copy's GC away; reclaim explicitly now
            still_here = s.backing[v] == LOCAL
            s = lax.cond(jnp.logical_and(still_here, s.live_count[v] == 0),
                         lambda s: paths.free_page(cfg, s, v), lambda s: s, s)
            return s._replace(stats=st.bump(s.stats, evac_pages=1))

        return lax.cond(selected, evacuate_page, lambda s: s, s)

    s = lax.fori_loop(0, k, page_body, s)
    return s._replace(access=jnp.zeros_like(s.access))


# --------------------------------------------------------------------------
# maintenance / introspection
# --------------------------------------------------------------------------

def writeback_all(cfg: PlaneConfig, s: st.PlaneState) -> st.PlaneState:
    """Flush every dirty local page to the slab (keeps pages resident)."""

    def body(f, s):
        v = s.vpage_of[f]
        flush = jnp.logical_and(v >= 0, s.dirty[jnp.maximum(v, 0)])

        def do(s):
            slab = lax.dynamic_update_index_in_dim(s.slab, s.frames[f], v, axis=0)
            return s._replace(slab=slab, dirty=s.dirty.at[v].set(False))

        return lax.cond(flush, do, lambda s: s, s)

    return lax.fori_loop(0, cfg.num_frames, body, s)


def evict_all(cfg: PlaneConfig, s: st.PlaneState) -> st.PlaneState:
    """Page out every unpinned local page (shutdown / memory-pressure)."""

    def body(f, s):
        v = s.vpage_of[f]
        can = jnp.logical_and(v >= 0, s.pin[jnp.maximum(v, 0)] == 0)
        return lax.cond(can, lambda s: paths.page_out(cfg, s, f), lambda s: s, s)

    return lax.fori_loop(0, cfg.num_frames, body, s)


def peek(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray) -> jnp.ndarray:
    """Read object rows wherever they live, with NO state change (oracle)."""
    vaddr = s.obj_loc[obj_ids]
    v, slot = vaddr // cfg.page_objs, vaddr % cfg.page_objs
    local = s.backing[v] == LOCAL
    f = jnp.maximum(s.frame_of[v], 0)
    return jnp.where(local[:, None], s.frames[f, slot], s.slab[v, slot])


def occupancy(cfg: PlaneConfig, s: st.PlaneState) -> jnp.ndarray:
    """Fraction of local frames in use."""
    return jnp.mean((s.vpage_of >= 0).astype(jnp.float32))


def paging_fraction(cfg: PlaneConfig, s: st.PlaneState) -> jnp.ndarray:
    """Fraction of allocated pages whose PSF is paging (paper Fig. 7)."""
    allocated = s.backing != FREE
    pg = jnp.sum((s.psf & allocated).astype(jnp.int32))
    return pg / jnp.maximum(jnp.sum(allocated.astype(jnp.int32)), 1)


def check_invariants(cfg: PlaneConfig, s: st.PlaneState) -> dict:
    """Structural invariants (host-side; used by property tests)."""
    sn = jax.device_get(s)
    P, V, F = cfg.page_objs, cfg.num_vpages, cfg.num_frames
    out = {}

    # smart pointers and slot occupancy agree
    ok = True
    for o in range(cfg.num_objs):
        va = int(sn.obj_loc[o])
        if va < 0:
            continue
        ok &= sn.obj_of[va // P, va % P] == o
    out["obj_loc_obj_of_consistent"] = bool(ok)

    live = (sn.obj_of >= 0).sum(axis=1)
    out["live_count_correct"] = bool(np.all(live == sn.live_count))
    out["alloc_ge_live"] = bool(np.all(sn.alloc_count >= sn.live_count))

    # frame table is a bijection on LOCAL pages
    ok = True
    for v in range(V):
        if sn.backing[v] == LOCAL:
            f = int(sn.frame_of[v])
            ok &= 0 <= f < F and sn.vpage_of[f] == v
        else:
            ok &= sn.frame_of[v] == -1
    for f in range(F):
        v = int(sn.vpage_of[f])
        if v >= 0:
            ok &= sn.backing[v] == LOCAL and sn.frame_of[v] == f
    out["frame_bijection"] = bool(ok)

    out["pins_nonnegative"] = bool(np.all(sn.pin >= 0))
    # outside an access batch the only standing pins are the fill cursors
    cursors = [int(sn.fill_vpage), int(sn.evac_hot_vpage),
               int(sn.evac_cold_vpage), int(sn.remote_fill_vpage)]
    expected = np.zeros(V, np.int64)
    for c in cursors:
        if c >= 0:
            expected[c] += 1
    out["pins_are_cursor_pins"] = bool(np.all(sn.pin == expected))
    out["free_pages_empty"] = bool(np.all(sn.live_count[sn.backing == FREE] == 0))
    return out
