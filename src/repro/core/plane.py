"""The Atlas hybrid data plane: batched access, evacuation, writeback.

``access`` is the batched read barrier (paper Algorithm 1/2), served by the
plan-then-execute engine in :mod:`repro.core.batch`: the whole request
batch is classified against the batch-entry state, misses are deduped and
split by PSF into a paging plan (whole-page fetches, vaddrs stable) and a
runtime plan (objects moved to the ingress fill page, smart pointers
rewritten), profiling (CAT card bits, access bits, page clocks) is applied
in one vectorized pass, and results are read with one batched gather.
``mode="reference"`` replays the same plan through a scalar executor — the
equivalence oracle.

Eviction happens only page-granularly inside ``paths.alloc_frame`` (egress
path, paper §4.1) — the PSF of the victim is recomputed from its CAR
there.  ``evacuate`` is the concurrent compactor analogue: victims are
selected by garbage ratio and their live rows are re-packed hot/cold
through the ``kernels.compact`` page-assembly kernel.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels import ops as kops
from . import batch as batch_lib
from . import paths
from . import state as st
from .layout import (CAR_THR_MAX, CAR_THR_MIN, FREE, LOCAL, REMOTE,
                     PlaneConfig)


# --------------------------------------------------------------------------
# batched access (the hybrid ingress) — plan-then-execute engine
# --------------------------------------------------------------------------

def access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray, *,
           mode: str | None = None):
    """Batched hybrid access (the read barrier; DESIGN.md §3).

    Shape contract: ``obj_ids`` is ``[R]`` int32, negative ids are padded
    no-ops; returns ``(state, rows[R, D])`` with zero rows for padded or
    fault-unserved requests.  Determinism invariant: ``mode="batch"``
    (vectorized engine, default) and ``mode="reference"`` (scalar oracle)
    execute the identical plan and agree byte-for-byte on state and rows;
    ``None`` defers to ``cfg.access_mode``."""
    return batch_lib.access(cfg, s, obj_ids, mode=mode)


def update(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
           rows: jnp.ndarray, *, mode: str | None = None) -> st.PlaneState:
    """Batched write-through-local: fault in, overwrite rows, mark dirty
    (DESIGN.md §3; fault masking §6/§6c).

    Shape contract: ``obj_ids`` ``[R]`` int32 (negative = padded no-op),
    ``rows`` ``[R, D]``; returns the new state.  Determinism invariant: a
    fault-masked (unserved) request writes nothing to either tier — under
    any same-seed schedule both access modes produce bit-identical
    states."""
    return batch_lib.update(cfg, s, obj_ids, rows, mode=mode)


# --------------------------------------------------------------------------
# memoized jit entry points
# --------------------------------------------------------------------------
# ``jax.jit(partial(access, cfg))`` builds a NEW callable every time, so two
# call sites with the same config compile the same program twice.  These
# helpers key the jitted executable on the (hashable) PlaneConfig — every
# engine/test/benchmark in a process shares one compilation per config.
# The thin wrappers normalize defaulted arguments before the cache lookup
# (lru_cache keys raw call args, so ``f(cfg)`` and ``f(cfg, "batch")``
# would otherwise compile twice).

@functools.lru_cache(maxsize=None)
def _jitted_access(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(access, cfg, mode=mode))


def jitted_access(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_access(cfg, mode or cfg.access_mode)


@functools.lru_cache(maxsize=None)
def _jitted_update(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(update, cfg, mode=mode))


def jitted_update(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_update(cfg, mode or cfg.access_mode)


# plan/execute split entry points: the serving engine dispatches these as
# two device calls per batch so the host can enqueue batch N+1's plan while
# batch N's execute runs (double-buffered dispatch, see serving.engine)

@functools.lru_cache(maxsize=None)
def _jitted_plan_access(cfg: PlaneConfig, degraded: bool):
    return jax.jit(partial(batch_lib.plan_access, cfg, degraded=degraded))


def jitted_plan_access(cfg: PlaneConfig, degraded: bool = False):
    return _jitted_plan_access(cfg, degraded)


@functools.lru_cache(maxsize=None)
def _jitted_execute_access(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(batch_lib.execute_access, cfg, mode=mode))


def jitted_execute_access(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_execute_access(cfg, mode or cfg.access_mode)


@functools.lru_cache(maxsize=None)
def _jitted_evacuate(cfg: PlaneConfig, garbage_threshold: float | None,
                     max_pages: int, clear_access: bool):
    return jax.jit(partial(evacuate, cfg, garbage_threshold=garbage_threshold,
                           max_pages=max_pages, clear_access=clear_access))


def jitted_evacuate(cfg: PlaneConfig, garbage_threshold: float | None = None,
                    max_pages: int = 16, clear_access: bool = True):
    return _jitted_evacuate(cfg, garbage_threshold, max_pages, clear_access)


@functools.lru_cache(maxsize=None)
def _jitted_plan_evacuate(cfg: PlaneConfig, garbage_threshold: float | None,
                          max_pages: int):
    return jax.jit(partial(plan_evacuate, cfg,
                           garbage_threshold=garbage_threshold,
                           max_pages=max_pages))


def jitted_plan_evacuate(cfg: PlaneConfig,
                         garbage_threshold: float | None = None,
                         max_pages: int = 16):
    return _jitted_plan_evacuate(cfg, garbage_threshold, max_pages)


@functools.lru_cache(maxsize=None)
def _jitted_execute_evacuate(cfg: PlaneConfig,
                             garbage_threshold: float | None,
                             clear_access: bool):
    return jax.jit(partial(execute_evacuate, cfg,
                           garbage_threshold=garbage_threshold,
                           clear_access=clear_access))


def jitted_execute_evacuate(cfg: PlaneConfig,
                            garbage_threshold: float | None = None,
                            clear_access: bool = True):
    return _jitted_execute_evacuate(cfg, garbage_threshold, clear_access)


@functools.lru_cache(maxsize=None)
def _jitted_advance_epoch(cfg: PlaneConfig):
    return jax.jit(partial(advance_epoch, cfg))


def jitted_advance_epoch(cfg: PlaneConfig):
    return _jitted_advance_epoch(cfg)


# --------------------------------------------------------------------------
# epoch governor (always-on profiling, adaptive path selection)
# --------------------------------------------------------------------------

def advance_epoch(cfg: PlaneConfig, s: st.PlaneState, *,
                  traffic=None) -> st.PlaneState:
    """Close one profiling epoch: fold the card-table window into the
    per-page CAR EMA (``kernels.cat_decay``), let the governor adapt the
    PSF threshold from the epoch's observed paging-vs-runtime traffic, and
    recompute every allocated page's PSF from the decayed CAR — path
    selection adapts *online*, without waiting for a page-out.

    Governor law: with ``d_page``/``d_obj`` the bytes each ingress path
    moved since the last epoch, the threshold moves by ``governor_gain *
    (d_page - d_obj) / total`` (clipped to [CAR_THR_MIN, CAR_THR_MAX]).
    When paging traffic dominates, the bar for the paging path rises —
    sparse pages that were amplifying I/O drop to the runtime path; when
    object traffic dominates, the bar falls and co-accessed pages return
    to bulk paging.  At equilibrium the two paths carry comparable bytes,
    which is where the hybrid's amplification-vs-overhead tradeoff sits
    (paper Fig. 10's flat optimum around 0.8-0.9).

    The card table is cleared to open the next window (``page_out``
    therefore blends the instantaneous window CAR with the EMA).  Pure
    vectorized ``state -> state`` math — identical under both access
    modes, bit-deterministic (no RNG, no data-dependent shapes).  Owned
    by DESIGN.md §4a.

    ``traffic``: optional ``(d_page, d_obj)`` float32 byte totals overriding
    the locally-derived deltas — the sharded plane passes the GLOBAL
    aggregate here so every shard's governor sees the same imbalance (and
    their thresholds move in lockstep), while all other epoch state stays
    per-shard."""
    allocated = s.backing != FREE
    ema = kops.cat_decay(s.cat, s.car_ema, s.alloc_count,
                         decay=cfg.car_decay, impl=cfg.kernel_impl)
    ema = jnp.where(allocated, ema, 0.0)

    if traffic is None:
        d_page = ((s.stats.page_ins - s.epoch_page_ins).astype(jnp.float32)
                  * cfg.page_bytes)
        d_obj = ((s.stats.obj_ins - s.epoch_obj_ins).astype(jnp.float32)
                 * cfg.row_bytes)
    else:
        d_page, d_obj = traffic
    total = d_page + d_obj
    imbalance = jnp.where(total > 0.0,
                          (d_page - d_obj) / jnp.maximum(total, 1.0), 0.0)
    thr = jnp.clip(s.car_thr + jnp.float32(cfg.governor_gain) * imbalance,
                   CAR_THR_MIN, CAR_THR_MAX)

    new_psf = jnp.where(allocated, ema >= thr, s.psf)
    flip_p = jnp.sum((allocated & ~s.psf & new_psf).astype(jnp.int32))
    flip_r = jnp.sum((allocated & s.psf & ~new_psf).astype(jnp.int32))
    return s._replace(
        cat=jnp.zeros_like(s.cat),        # open the next epoch window
        car_ema=ema, car_thr=thr, psf=new_psf,
        epoch=s.epoch + 1,
        epoch_page_ins=s.stats.page_ins, epoch_obj_ins=s.stats.obj_ins,
        stats=st.bump(s.stats, epochs=1, psf_to_paging=flip_p,
                      psf_to_runtime=flip_r))


# --------------------------------------------------------------------------
# evacuation (concurrent compactor analogue, paper §4.3)
# --------------------------------------------------------------------------

class EvacPlan(NamedTuple):
    """Victim selection for one evacuation slice (fixed ``[k]`` shapes, so
    the serving engine can dispatch planning and execution as separate
    async device calls into pipeline bubbles)."""

    victims: jnp.ndarray   # [k] int32 candidate vpages (garbage-ratio top-k)
    ok: jnp.ndarray        # [k] bool  candidate was eligible at plan time


def plan_evacuate(cfg: PlaneConfig, s: st.PlaneState,
                  garbage_threshold: float | None = None,
                  max_pages: int = 16) -> EvacPlan:
    """Select at most ``max_pages`` evacuation victims: the local, unpinned
    pages with the highest dead-slot ratio above the threshold."""
    thr = (cfg.evac_garbage_threshold if garbage_threshold is None
           else garbage_threshold)
    allocated_all = s.alloc_count
    dead_all = allocated_all - s.live_count
    ratio_all = dead_all.astype(jnp.float32) / jnp.maximum(allocated_all, 1)
    eligible = ((s.backing == LOCAL) & (s.pin == 0) & (allocated_all > 0)
                & (ratio_all > thr))
    score = jnp.where(eligible, ratio_all, -1.0)
    k = min(max_pages, cfg.num_vpages)
    _, victims = lax.top_k(score, k)
    return EvacPlan(victims=victims, ok=score[victims] > -1.0)


def execute_evacuate(cfg: PlaneConfig, s: st.PlaneState, plan: EvacPlan,
                     garbage_threshold: float | None = None, *,
                     clear_access: bool = True, shard=None) -> st.PlaneState:
    """Compact the planned victim pages (hot/cold segregation by access
    bit, ``kernels.compact`` page assembly).  Each victim's eligibility is
    re-checked against the *current* state — a stale plan entry (page
    evicted, drained, or pinned since planning) is skipped, so a plan may
    safely execute several dispatch gaps after it was made.

    Egress faults (DESIGN.md §6c) gate each victim the same way: when
    ``cfg.faults.egress_fail(s.step, vpage, shard)`` holds, the victim is
    skipped whole this slice — no rows move, no page is freed, and
    ``stats.egress_failures`` counts the blocked compaction.  The source
    page stays live and eligible, so a later slice retries it.

    ``clear_access=False`` keeps the access bits (paper: the evacuator
    clears them "at the end of each evacuation" — for background slices
    that is the end of a full round, not of every slice; the serving
    engine clears on its round boundary)."""
    thr = (cfg.evac_garbage_threshold if garbage_threshold is None
           else garbage_threshold)
    P, V, F, O = cfg.page_objs, cfg.num_vpages, cfg.num_frames, cfg.num_objs
    D = cfg.obj_dim
    victims, victim_ok = plan.victims, plan.ok
    k = victims.shape[0]
    fc = cfg.faults
    shard_i = 0 if shard is None else shard

    def page_body(i, s):
        v = victims[i]
        # re-check eligibility against the *current* state (earlier victims
        # may have evicted or drained this page while allocating
        # destination frames)
        allocated = s.alloc_count[v]
        dead = allocated - s.live_count[v]
        garbage_ratio = dead.astype(jnp.float32) / jnp.maximum(allocated, 1)
        selected = (
            victim_ok[i]
            & (s.backing[v] == LOCAL)
            & (s.pin[v] == 0)
            & (allocated > 0)
            & (garbage_ratio > thr)
        )
        if fc is not None and fc.egress_active:
            # an evacuation moves rows into (possibly fresh) remote-backed
            # log pages — a blocked write skips the victim atomically
            efail = fc.egress_fail(s.step, v, shard_i)
            s = s._replace(stats=st.bump(
                s.stats,
                egress_failures=(selected & efail).astype(jnp.int32)))
            selected = selected & ~efail

        def evacuate_page(s):
            # pin the source so destination allocation can't page it out
            # from under the compactor (Invariant #3 mechanism)
            s = paths.pin_page(s, v)
            f_src = jnp.maximum(s.frame_of[v], 0)
            objs = s.obj_of[v]                      # [P]
            occ = objs >= 0
            hotm = occ & s.access[v]
            coldm = occ & ~s.access[v]
            was_carded = s.cat[v]
            n_moved = jnp.sum(occ.astype(jnp.int32))

            # plan both append streams (allocates/pins fresh pages first;
            # retired cursors stay pinned until the compact writes land)
            s, hv, hslot, hcur, hc, hf, hret = batch_lib.plan_append_stream(
                cfg, s, "evac_hot_vpage", hotm)
            s, cv, cslot, ccur, cc, cf, cret = batch_lib.plan_append_stream(
                cfg, s, "evac_cold_vpage", coldm)
            v_dst = jnp.where(hotm, hv, cv)
            s_dst = jnp.where(hotm, hslot, cslot)

            # assemble the (up to four) destination pages with the compact
            # kernel: each destination slot DMAs its source row directly
            src_flat = f_src * P + jnp.arange(P, dtype=jnp.int32)
            dest_pages = jnp.stack([hc, hf, cc, cf])          # [4]
            dpi = jnp.where(hotm, jnp.where(hcur, 0, 1),
                            jnp.where(coldm, jnp.where(ccur, 2, 3), 4))
            plan = jnp.full((4, P), -1, jnp.int32)
            plan = plan.at[dpi, jnp.where(occ, s_dst, 0)].set(src_flat)
            assembled = kops.compact_pages(
                s.frames.reshape(F * P, D), plan.reshape(4 * P),
                page_objs=P, impl=cfg.kernel_impl)            # [4, P, D]
            dest_f = jnp.maximum(s.frame_of[jnp.maximum(dest_pages, 0)], 0)
            existing = s.frames[dest_f]
            merged = jnp.where((plan >= 0)[..., None], assembled, existing)
            frames = s.frames.at[jnp.where(dest_pages >= 0, dest_f, F)].set(
                merged)

            # smart pointers + occupancy + preserved profiling bits
            # (the evacuator preserves card bits across the move, §4.3)
            dst_flat = jnp.where(occ, v_dst * P + s_dst, V * P)
            s = s._replace(
                frames=frames,
                obj_loc=s.obj_loc.at[jnp.where(occ, objs, O)].set(
                    v_dst * P + s_dst),
                obj_of=s.obj_of.reshape(V * P).at[dst_flat].set(
                    objs).reshape(V, P),
                cat=s.cat.reshape(V * P).at[dst_flat].set(
                    was_carded).reshape(V, P),
                access=s.access.reshape(V * P).at[dst_flat].set(
                    hotm).reshape(V, P),
                stats=st.bump(s.stats, evac_moved=n_moved),
            )
            # the moved rows are in place — NOW the retired cursors may be
            # unpinned (they are ordinary unpinned pages from here on)
            pin = s.pin.at[jnp.where(hret >= 0, hret, V)].add(-1)
            pin = pin.at[jnp.where(cret >= 0, cret, V)].add(-1)
            s = s._replace(pin=pin)
            # kill the source copies wholesale
            s = s._replace(obj_of=s.obj_of.at[v].set(-1),
                           live_count=s.live_count.at[v].set(0))
            s = paths.unpin_page(s, v)
            # the pin kept GC away; reclaim the drained source explicitly
            still_here = s.backing[v] == LOCAL
            s = lax.cond(jnp.logical_and(still_here, s.live_count[v] == 0),
                         lambda s: paths.free_page(cfg, s, v), lambda s: s, s)
            return s._replace(stats=st.bump(s.stats, evac_pages=1))

        return lax.cond(selected, evacuate_page, lambda s: s, s)

    s = lax.fori_loop(0, k, page_body, s)
    if clear_access:
        s = s._replace(access=jnp.zeros_like(s.access))
    return s


def evacuate(cfg: PlaneConfig, s: st.PlaneState,
             garbage_threshold: float | None = None,
             max_pages: int = 16, *,
             clear_access: bool = True, shard=None) -> st.PlaneState:
    """Foreground evacuation: plan + execute in one call.

    Live objects are segregated by their access bit: recently-accessed
    ("hot") objects are appended to a dedicated hot destination page,
    the rest to a cold one — manufacturing the spatial locality that lets
    subsequent accesses take the cheap paging path.  Each victim's moves
    are planned as two append streams and executed with the
    ``kernels.compact`` page-assembly kernel (one gather-DMA per
    destination page) instead of a per-slot append chain.  All access bits
    are cleared at the end (paper: "cleared by the evacuator at the end of
    each evacuation").

    Evacuation is *incremental*: at most ``max_pages`` victims (the highest
    garbage ratios) are compacted per call, bounding the pause the
    concurrent evacuator imposes on the application.  The serving engine
    goes further and schedules ``plan_evacuate``/``execute_evacuate`` as
    small background slices inside pipeline bubbles (``evac_budget``) —
    this wrapper is the blocking-foreground composition of the same two
    halves.

    Shape contract: pure ``state -> state`` (fixed ``[max_pages]`` victim
    plan).  Determinism invariant: victim selection and the egress-fault
    gate (§6c) are functions of state and ``cfg.faults`` only — same-seed
    runs compact identical pages.  Owned by DESIGN.md §4c (slice
    scheduling) and §6c (egress faults); ``shard`` keys the per-shard
    fault stream for the sharded plane."""
    plan = plan_evacuate(cfg, s, garbage_threshold, max_pages)
    return execute_evacuate(cfg, s, plan, garbage_threshold,
                            clear_access=clear_access, shard=shard)


# --------------------------------------------------------------------------
# maintenance / introspection
# --------------------------------------------------------------------------

def writeback_all(cfg: PlaneConfig, s: st.PlaneState) -> st.PlaneState:
    """Flush every dirty local page to the slab (keeps pages resident)."""

    def body(f, s):
        v = s.vpage_of[f]
        flush = jnp.logical_and(v >= 0, s.dirty[jnp.maximum(v, 0)])

        def do(s):
            slab = lax.dynamic_update_index_in_dim(s.slab, s.frames[f], v, axis=0)
            return s._replace(slab=slab, dirty=s.dirty.at[v].set(False))

        return lax.cond(flush, do, lambda s: s, s)

    return lax.fori_loop(0, cfg.num_frames, body, s)


def evict_all(cfg: PlaneConfig, s: st.PlaneState) -> st.PlaneState:
    """Page out every unpinned local page (shutdown / memory-pressure)."""

    def body(f, s):
        v = s.vpage_of[f]
        can = jnp.logical_and(v >= 0, s.pin[jnp.maximum(v, 0)] == 0)
        return lax.cond(can, lambda s: paths.page_out(cfg, s, f), lambda s: s, s)

    return lax.fori_loop(0, cfg.num_frames, body, s)


def peek(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray) -> jnp.ndarray:
    """Read object rows wherever they live, with NO state change (oracle)."""
    vaddr = s.obj_loc[obj_ids]
    v, slot = vaddr // cfg.page_objs, vaddr % cfg.page_objs
    local = s.backing[v] == LOCAL
    f = jnp.maximum(s.frame_of[v], 0)
    return jnp.where(local[:, None], s.frames[f, slot], s.slab[v, slot])


def occupancy(cfg: PlaneConfig, s: st.PlaneState) -> jnp.ndarray:
    """Fraction of local frames in use."""
    return jnp.mean((s.vpage_of >= 0).astype(jnp.float32))


def paging_fraction(cfg: PlaneConfig, s: st.PlaneState) -> jnp.ndarray:
    """Fraction of allocated pages whose PSF is paging (paper Fig. 7)."""
    allocated = s.backing != FREE
    pg = jnp.sum((s.psf & allocated).astype(jnp.int32))
    return pg / jnp.maximum(jnp.sum(allocated.astype(jnp.int32)), 1)


def check_invariants(cfg: PlaneConfig, s: st.PlaneState) -> dict:
    """Structural invariants (host-side; used by property tests)."""
    sn = jax.device_get(s)
    P, V, F = cfg.page_objs, cfg.num_vpages, cfg.num_frames
    out = {}

    # smart pointers and slot occupancy agree
    ok = True
    for o in range(cfg.num_objs):
        va = int(sn.obj_loc[o])
        if va < 0:
            continue
        ok &= sn.obj_of[va // P, va % P] == o
    out["obj_loc_obj_of_consistent"] = bool(ok)

    live = (sn.obj_of >= 0).sum(axis=1)
    out["live_count_correct"] = bool(np.all(live == sn.live_count))
    out["alloc_ge_live"] = bool(np.all(sn.alloc_count >= sn.live_count))

    # frame table is a bijection on LOCAL pages
    ok = True
    for v in range(V):
        if sn.backing[v] == LOCAL:
            f = int(sn.frame_of[v])
            ok &= 0 <= f < F and sn.vpage_of[f] == v
        else:
            ok &= sn.frame_of[v] == -1
    for f in range(F):
        v = int(sn.vpage_of[f])
        if v >= 0:
            ok &= sn.backing[v] == LOCAL and sn.frame_of[v] == f
    out["frame_bijection"] = bool(ok)

    out["pins_nonnegative"] = bool(np.all(sn.pin >= 0))
    # outside an access batch the only standing pins are the fill cursors
    cursors = [int(sn.fill_vpage), int(sn.evac_hot_vpage),
               int(sn.evac_cold_vpage), int(sn.remote_fill_vpage)]
    expected = np.zeros(V, np.int64)
    for c in cursors:
        if c >= 0:
            expected[c] += 1
    out["pins_are_cursor_pins"] = bool(np.all(sn.pin == expected))
    out["free_pages_empty"] = bool(np.all(sn.live_count[sn.backing == FREE] == 0))
    return out
