"""Computation offloading (paper §4.3 "Computation offloading").

Atlas reserves an *offload space* whose pages keep address alignment between
the compute and memory servers, so functions can run remotely on objects
without fetching them.  The space is object-in / page-out only.

TPU adaptation: the far tier (slab) is addressable by reduction kernels
without staging rows into frames, because vaddrs are *always* stable at
page-out in our design (slab slot id == vpage id).  "Running a function on
the remote side" therefore becomes: execute the reduction directly against
slab storage and return only the (small) result — exactly the traffic-saving
the paper is after.  The flagship use is sparse-attention page scoring
(``kernels.topk_pages``): page summaries are computed against far-resident
KV pages, and only the winning pages are fetched.

The ``offload`` bit in the smart pointer becomes a per-page ``offload_busy``
mask the runtime must respect before object-fetching (we expose it as an
extra pin so the existing victim/evacuation masking enforces it).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from ..kernels import ops as kops
from . import state as st
from .layout import REMOTE, PlaneConfig


def remote_apply(cfg: PlaneConfig, s: st.PlaneState, vpages: jnp.ndarray,
                 fn: Callable[[jnp.ndarray], jnp.ndarray]):
    """Run ``fn`` on far-resident pages *without fetching them*.

    ``fn`` maps ``[P, D] -> [...]`` and is vmapped over the requested pages.
    Pages that are actually local are served from frames (free consistency:
    there is never more than one live copy of a page).  Each page is
    gathered from exactly ONE tier via masked page-granular gathers (a
    page's index into the other tier is ``-1``) — the traffic-saving
    primitive must not move both the frame and the slab copy of every
    requested page.  Returns ``(state, results)``; the touched pages are
    pinned for the duration via the offload bit analogue (caller releases
    with :func:`remote_release`)."""
    import jax

    P, D, V, F = cfg.page_objs, cfg.obj_dim, cfg.num_vpages, cfg.num_frames
    local = s.backing[vpages] != REMOTE
    fidx = jnp.where(local, jnp.maximum(s.frame_of[vpages], 0), -1)
    sidx = jnp.where(local, -1, vpages)
    from_frames = kops.gather_rows(s.frames.reshape(F, P * D), fidx,
                                   impl=cfg.kernel_impl)
    from_slab = kops.gather_rows(s.slab.reshape(V, P * D), sidx,
                                 impl=cfg.kernel_impl)
    pages = jnp.where(local[:, None], from_frames,
                      from_slab).reshape(-1, P, D)
    results = jax.vmap(fn)(pages)
    s = s._replace(pin=s.pin.at[vpages].add(1))   # offload-busy
    return s, results


def remote_release(cfg: PlaneConfig, s: st.PlaneState, vpages: jnp.ndarray
                   ) -> st.PlaneState:
    """Clear the offload-busy pins taken by :func:`remote_apply`."""
    return s._replace(pin=s.pin.at[vpages].add(-1))
