"""Plane state pytree and constructors."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layout
from .layout import FREE, LOCAL, REMOTE, PlaneConfig


class PlaneStats(NamedTuple):
    """Event counters (int32 counts; byte totals derived host-side via
    ``PlaneConfig.row_bytes``/``page_bytes`` so no 64-bit arithmetic is needed
    on device)."""

    hits: jnp.ndarray            # resident accesses
    misses: jnp.ndarray          # faulting accesses
    page_ins: jnp.ndarray        # paging-path ingress events (pages)
    obj_ins: jnp.ndarray         # runtime-path ingress events (objects)
    page_outs: jnp.ndarray       # egress events (pages)
    dirty_page_outs: jnp.ndarray # egress events that wrote data back
    psf_to_paging: jnp.ndarray   # PSF flips runtime->paging (page-out / epoch)
    psf_to_runtime: jnp.ndarray  # PSF flips paging->runtime (page-out / epoch)
    evac_moved: jnp.ndarray      # objects moved by the evacuator
    evac_pages: jnp.ndarray      # pages reclaimed by the evacuator
    obj_outs: jnp.ndarray        # object-granular egress (object-plane baseline)
    lru_scans: jnp.ndarray       # objects scanned by object-level LRU (baseline)
    prefetch_issued: jnp.ndarray # prefetch page-ins (subset of page_ins)
    prefetch_used: jnp.ndarray   # prefetched pages later hit by a demand access
    epochs: jnp.ndarray          # advance_epoch invocations (governor runs)
    ingress_spills: jnp.ndarray  # sharded-exchange requests deferred a round
    #                              (per_shard_budget overflow, shardplane)
    fetch_failures: jnp.ndarray  # planned fetches masked off by the fault
    #                              model (repro.core.faults) — each left its
    #                              request unserved this tick
    egress_failures: jnp.ndarray # remote writes (eviction writeback, remote
    #                              update, evacuation victim, KV append)
    #                              blocked by the fault model — the write was
    #                              skipped atomically, neither tier mutated

    @classmethod
    def zeros(cls) -> "PlaneStats":
        z = jnp.zeros((), jnp.int32)
        return cls(*([z] * len(cls._fields)))


class PlaneState(NamedTuple):
    """Functional state of the hybrid data plane.

    All shapes are static; every plane operation is a pure
    ``(state, request) -> (state, result)`` function (jit/shard_map safe).
    """

    # --- storage tiers -------------------------------------------------
    frames: jnp.ndarray      # [F, P, D]  local tier ("HBM")
    slab: jnp.ndarray        # [V, P, D]  far tier  (slot id == vpage id)
    # --- page tables ----------------------------------------------------
    backing: jnp.ndarray     # [V] int8   FREE / LOCAL / REMOTE
    frame_of: jnp.ndarray    # [V] int32  frame id when LOCAL else -1
    vpage_of: jnp.ndarray    # [F] int32  inverse map, -1 = free frame
    # --- smart pointers ---------------------------------------------------
    obj_loc: jnp.ndarray     # [O] int32  vaddr (vpage*P + slot), -1 = unallocated
    obj_of: jnp.ndarray      # [V, P] int32  occupant object id, -1 = dead/empty
    live_count: jnp.ndarray  # [V] int32  live slots
    alloc_count: jnp.ndarray # [V] int32  slots ever allocated (log cursor)
    # --- always-on profiling (paper §4.1/4.3) ----------------------------
    cat: jnp.ndarray         # [V, P] bool  card access table (epoch window)
    psf: jnp.ndarray         # [V] bool     path selector flag (True = paging)
    access: jnp.ndarray      # [V, P] bool  access bit since last evacuation
    # --- epoch governor (adaptive path selection, Atlas's control loop) ---
    car_ema: jnp.ndarray     # [V] f32  decayed CAR (advance_epoch)
    car_thr: jnp.ndarray     # [] f32   adaptive PSF threshold (governor)
    epoch: jnp.ndarray       # [] int32 epoch counter
    epoch_page_ins: jnp.ndarray  # [] int32 stats.page_ins at last epoch
    epoch_obj_ins: jnp.ndarray   # [] int32 stats.obj_ins at last epoch
    prefetched: jnp.ndarray  # [V] bool  prefetched, not yet demand-touched
    # --- residency metadata ----------------------------------------------
    pin: jnp.ndarray         # [V] int32  deref counts (Invariants #2/#3)
    dirty: jnp.ndarray       # [V] bool   modified since last writeback
    clock: jnp.ndarray       # [V] int32  last-touch step (page-level recency)
    # --- log-structured allocator cursors ---------------------------------
    fill_vpage: jnp.ndarray      # [] int32  ingress fill page (-1 = none)
    evac_hot_vpage: jnp.ndarray  # [] int32  evacuation hot destination (-1)
    evac_cold_vpage: jnp.ndarray # [] int32  evacuation cold destination (-1)
    remote_fill_vpage: jnp.ndarray  # [] int32  remote log page (object-plane egress)
    step: jnp.ndarray            # [] int32  logical time
    # --- object-plane baseline metadata ------------------------------------
    obj_last: jnp.ndarray    # [O] int32  per-object last access (AIFM LRU analogue)
    lru_hand: jnp.ndarray    # [] int32   rotating scan hand for budgeted LRU
    stats: PlaneStats


def create(cfg: PlaneConfig, initial: jnp.ndarray) -> PlaneState:
    """Build a plane holding ``initial`` ([num_objs, obj_dim]) entirely in the
    far tier, densely packed into the first ``data_pages`` vpages."""
    O, D = cfg.num_objs, cfg.obj_dim
    V, P, F = cfg.num_vpages, cfg.page_objs, cfg.num_frames
    assert initial.shape == (O, D), (initial.shape, (O, D))

    dp = cfg.data_pages
    slab = jnp.zeros((V, P, D), cfg.dtype)
    pad = dp * P - O
    packed = jnp.concatenate([initial.astype(cfg.dtype),
                              jnp.zeros((pad, D), cfg.dtype)], axis=0)
    slab = slab.at[:dp].set(packed.reshape(dp, P, D))

    obj_of = jnp.full((V, P), -1, jnp.int32)
    ids = jnp.concatenate([jnp.arange(O, dtype=jnp.int32),
                           jnp.full((pad,), -1, jnp.int32)])
    obj_of = obj_of.at[:dp].set(ids.reshape(dp, P))

    # live/alloc counts for the packed prefix (last page may be partial)
    counts = np.full((V,), 0, np.int32)
    counts[:dp] = P
    if pad:
        counts[dp - 1] = P - pad
    counts = jnp.asarray(counts)

    backing = jnp.where(jnp.arange(V) < dp, REMOTE, FREE).astype(jnp.int8)

    return PlaneState(
        frames=jnp.zeros((F, P, D), cfg.dtype),
        slab=slab,
        backing=backing,
        frame_of=jnp.full((V,), -1, jnp.int32),
        vpage_of=jnp.full((F,), -1, jnp.int32),
        obj_loc=jnp.arange(O, dtype=jnp.int32),
        obj_of=obj_of,
        live_count=counts,
        alloc_count=counts,
        cat=jnp.zeros((V, P), bool),
        psf=jnp.full((V,), cfg.psf_init_paging, bool),
        access=jnp.zeros((V, P), bool),
        car_ema=jnp.zeros((V,), jnp.float32),
        car_thr=jnp.asarray(cfg.car_threshold, jnp.float32),
        epoch=jnp.asarray(0, jnp.int32),
        epoch_page_ins=jnp.asarray(0, jnp.int32),
        epoch_obj_ins=jnp.asarray(0, jnp.int32),
        prefetched=jnp.zeros((V,), bool),
        pin=jnp.zeros((V,), jnp.int32),
        dirty=jnp.zeros((V,), bool),
        clock=jnp.zeros((V,), jnp.int32),
        fill_vpage=jnp.asarray(-1, jnp.int32),
        evac_hot_vpage=jnp.asarray(-1, jnp.int32),
        evac_cold_vpage=jnp.asarray(-1, jnp.int32),
        remote_fill_vpage=jnp.asarray(-1, jnp.int32),
        step=jnp.asarray(0, jnp.int32),
        obj_last=jnp.zeros((O,), jnp.int32),
        lru_hand=jnp.asarray(0, jnp.int32),
        stats=PlaneStats.zeros(),
    )


def bump(stats: PlaneStats, **deltas) -> PlaneStats:
    """Increment named counters."""
    return stats._replace(**{k: getattr(stats, k) + v for k, v in deltas.items()})


# --------------------------------------------------------------------------
# shard-aware layout (the sharded far tier, repro.core.shardplane)
# --------------------------------------------------------------------------

def create_sharded(cfg: PlaneConfig, shards: int,
                   initial: jnp.ndarray) -> PlaneState:
    """Stacked ``[shards, ...]`` plane state: shard ``s`` owns global objects
    ``[s*O, (s+1)*O)`` (``O = cfg.num_objs`` is the PER-SHARD capacity), its
    own contiguous slab partition, frame pool, CAT/CAR/EMA profiling state
    and governor threshold.  ``cfg`` is the per-shard config; ``initial`` is
    the GLOBAL ``[shards*O, D]`` object array, split contiguously."""
    O, D = cfg.num_objs, cfg.obj_dim
    assert initial.shape == (shards * O, D), (initial.shape, (shards * O, D))
    return jax.vmap(lambda part: create(cfg, part))(
        initial.reshape(shards, O, D))


def shard_slice(state: PlaneState, i: int) -> PlaneState:
    """One shard's plane from a stacked ``[shards, ...]`` state (host-side
    introspection / per-shard invariant checks)."""
    return jax.tree.map(lambda x: x[i], state)
