"""Address layout for the Atlas hybrid data plane.

The plane manages a *log-structured virtual page space*.  Every object
(a tensor row) has a stable virtual address::

    vaddr = vpage * page_objs + slot

recorded in the smart-pointer table ``obj_loc``.  A virtual page is backed
either by a local **frame** (the HBM tier) or by its dedicated **slab slot**
(the far tier; slab slot id == vpage id, so slab allocation is implicit).

Paper mapping (Atlas, §4):
  * page            -> vpage / frame of ``page_objs`` rows
  * card (16 B)     -> one object slot (cards are per-object here; see DESIGN.md)
  * smart pointer   -> ``obj_loc`` indirection entry
  * paging path     -> rebind vpage backing slab<->frame, vaddrs unchanged
  * runtime path    -> move object rows to fill pages, rewriting ``obj_loc``
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax.numpy as jnp


def _default_kernel_impl() -> str:
    """Kernel dispatch default; CI sets REPRO_KERNEL_IMPL=interpret so the
    CPU suite executes the real Pallas kernel bodies in interpret mode."""
    return os.environ.get("REPRO_KERNEL_IMPL", "auto")

# Backing kinds for a virtual page.
FREE = 0     # unallocated vpage (available to the log allocator)
LOCAL = 1    # backed by a frame (local / HBM tier)
REMOTE = 2   # backed by its slab slot (far tier)

# PSF values (1-bit path selector flag per vpage).
PSF_RUNTIME = False  # object-fetch ingress
PSF_PAGING = True    # paging ingress

# Bounds the epoch governor may move the adaptive CAR threshold within
# (paper Fig. 10: thresholds below ~0.1 page prematurely, 1.0 never pages).
CAR_THR_MIN = 0.1
CAR_THR_MAX = 1.0


@dataclasses.dataclass(frozen=True)
class PlaneConfig:
    """Static configuration of a plane instance (hashable: usable as a jit
    static argument)."""

    num_objs: int              # object-id capacity O
    obj_dim: int               # row width D (elements)
    page_objs: int             # objects per page P
    num_frames: int            # local frames F (the "local memory" budget)
    num_vpages: int            # virtual pages V (>= ceil(O/P) + log headroom)
    car_threshold: float = 0.8       # initial CAR >= threshold => PSF=paging
    evac_garbage_threshold: float = 0.5  # dead/allocated ratio triggering evacuation
    readahead: int = 0         # sequential prefetch window (pages per miss)
    dtype: Any = jnp.float32
    # Prefetch planner (the paging plan's candidate section, repro.core.batch):
    prefetch: str = "sequential"     # "sequential" window | "majority" stride vote
    prefetch_budget: int = 8         # static cap on prefetch pages per batch
    # Epoch governor (repro.core.plane.advance_epoch):
    car_decay: float = 0.5           # CAR EMA decay per epoch
    governor_gain: float = 0.05      # car_threshold step per epoch (adaptive)
    # Object-plane (AIFM-analogue) baseline knobs:
    object_evict_batch: int = 8      # objects evicted per reclaim
    lru_scan_budget: int = 0         # 0 = unlimited scan; >0 models CPU-starved LRU
    psf_init_paging: bool = True     # pages start on the paging path (kernel default)
    # Batch ingress engine (repro.core.batch):
    access_mode: str = "batch"       # "batch" (vectorized) | "reference" (scalar oracle)
    kernel_impl: str = dataclasses.field(default_factory=_default_kernel_impl)
    # "auto" = Pallas on TPU / jnp ref elsewhere; "pallas" | "interpret" | "ref"
    # Fault model (repro.core.faults.Schedule; frozen => still hashable).
    # None and the null Schedule() are both bit-identical to no fault model.
    faults: Any = None

    def __post_init__(self):
        assert self.prefetch in ("sequential", "majority"), self.prefetch
        assert self.prefetch_budget >= 0
        assert self.num_vpages * self.page_objs >= self.num_objs, (
            "virtual page space must cover the object space")
        assert self.num_vpages >= self.data_pages + 4, (
            "need log headroom beyond the initial packing (fill pages)")
        assert self.num_frames >= 4, "need frames for fill pages + working set"

    @property
    def data_pages(self) -> int:
        """Pages used by the initial dense packing of the object space."""
        return -(-self.num_objs // self.page_objs)

    @property
    def row_bytes(self) -> int:
        return self.obj_dim * jnp.dtype(self.dtype).itemsize

    @property
    def page_bytes(self) -> int:
        return self.page_objs * self.row_bytes


def vaddr_of(vpage, slot, page_objs: int):
    return vpage * page_objs + slot


def split_vaddr(vaddr, page_objs: int):
    return vaddr // page_objs, vaddr % page_objs
