"""Deterministic fault model for the far tier.

Real disaggregated memory misbehaves: fetches fail transiently, remote
nodes stall, whole shards drop out for a window.  This module is the one
place that decides *when* — a seeded, stateless, counter-based schedule
(murmur-style integer hash of ``(seed, tick, key)``) that is

  * **jit-traceable**: :meth:`Schedule.fetch_fail` runs inside the
    compiled plan step and masks individual remote fetches, and
  * **host-replayable**: :meth:`Schedule.fails` / :meth:`Schedule.spike`
    evaluate the *same* bits in numpy, so the serving engine, the
    training orchestrator's failure drills, and the tests all consume
    one schedule type and agree exactly on which tick faults.

There is no RNG state anywhere — two runs with the same seed produce
bit-identical fault streams regardless of batch interleaving, which is
what makes chaos soak tests and the fault benchmarks reproducible.

Fault classes:

  * transient fetch failures — each remote fetch (keyed by vpage, or by
    ``seq*num_pages+page`` in the KV plane) independently fails with
    ``fail_prob`` at a given tick, optionally only inside a
    ``fail_window`` of ticks (the fault-window benchmarks);
  * transient egress failures — each remote *write* (eviction writeback,
    runtime-path update of a remote object, evacuation victim, KV append)
    independently fails with ``egress_prob``; the write is skipped
    atomically at plan time so neither tier is ever partially mutated
    (DESIGN.md §6c);
  * scheduled outages — ``(start, end, shard)`` windows during which a
    shard's far tier is unreachable in *both* directions (fetches and
    egress writes fail; ``shard == -1`` means all shards);
  * slow-but-alive windows — ``(start, end, shard, slow_us)`` windows
    during which a shard answers correctly but slowly; host-side extra
    latency only, never a failure, so a slowdown must not trip the
    circuit breaker (the slow ≠ dead distinction, DESIGN.md §6c);
  * latency spikes — host-side extra dispatch delay of ``spike_us`` with
    probability ``spike_prob`` per tick (the device model stays
    functional; variance is injected where wall time is actually
    measured);
  * explicit ``fail_at`` ticks — the orchestrator-drill style ("step 7
    dies"), kept for crash/recovery tests.

``Schedule`` is a frozen, hashable dataclass so it can sit inside
``PlaneConfig``/``KVPlaneConfig`` and key the memoized jit caches.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# distinct multipliers decorrelate the seed/tick/key streams before the
# finalizer; _SHARD_SALT decorrelates per-shard fault streams so a 2-shard
# run does not fault mirrored vpages in lockstep
_SEED_MUL = 0x9E3779B9
_TICK_MUL = 0x85EBCA6B
_KEY_MUL = 0xC2B2AE35
_SHARD_SALT = 0x01000193
_SPIKE_KEY = 0x5A1AD  # reserved key: the host-side latency-spike stream
# egress (remote-write) faults hash a different stream than fetch faults so
# a page whose fetch fails is not doomed to also fail its writeback
_EGRESS_SALT = 0x27D4EB2F


def _mix(h, xp):
    """32-bit finalizer (murmur3-style avalanche) on uint32 arrays."""
    h = h ^ (h >> 16)
    h = h * xp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * xp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return h


def _u01(seed, tick, key, xp):
    """Uniform [0,1) from (seed, tick, key); identical bits on host/device."""
    if xp is np:  # uint32 wraparound is the point; don't warn about it
        with np.errstate(over="ignore"):
            return _u01_raw(seed, tick, key, xp)
    return _u01_raw(seed, tick, key, xp)


def _u01_raw(seed, tick, key, xp):
    h = (xp.asarray(seed).astype(xp.uint32) * xp.uint32(_SEED_MUL)
         ^ xp.asarray(tick).astype(xp.uint32) * xp.uint32(_TICK_MUL)
         ^ xp.asarray(key).astype(xp.uint32) * xp.uint32(_KEY_MUL))
    return _mix(h, xp).astype(xp.float32) * xp.float32(2.0 ** -32)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A deterministic fault schedule (frozen ⇒ hashable ⇒ jit-cache key).

    Owned by DESIGN.md §6 (fetch side) and §6c (egress side + slowdowns).

    Determinism invariant: every predicate is a pure function of
    ``(seed, tick, key, shard)`` — no RNG state — so the device methods
    (:meth:`fetch_fail`, :meth:`egress_fail`, :meth:`in_outage`) and
    their host mirrors (:meth:`fails`, :meth:`fails_egress`) agree
    bitwise, and two same-seed runs fault identically regardless of
    batch interleaving or dispatch mode.

    The default instance is the null schedule: ``Schedule().active`` and
    ``Schedule().egress_active`` are False and every fault predicate is
    constant-false, so wiring it in is bit-identical to no fault model
    at all.
    """
    seed: int = 0
    fail_prob: float = 0.0          # per-fetch transient failure probability
    fail_window: tuple = ()         # (start, end): fail_prob only inside;
                                    # () = fail_prob applies at every tick
    outages: tuple = ()             # ((start_tick, end_tick, shard), ...)
    fail_at: tuple = ()             # ticks where the whole tier fails once
    spike_prob: float = 0.0         # per-tick latency-spike probability
    spike_us: float = 0.0           # extra dispatch latency when spiking
    egress_prob: float = 0.0        # per-write transient failure probability
    egress_window: tuple = ()       # (start, end): egress_prob only inside
    slowdowns: tuple = ()           # ((start, end, shard, slow_us), ...):
                                    # slow-but-alive windows, host-side only

    def __post_init__(self):
        # normalize to nested tuples so list-built schedules stay hashable
        object.__setattr__(self, "outages",
                           tuple(tuple(int(x) for x in w)
                                 for w in self.outages))
        object.__setattr__(self, "fail_at",
                           tuple(int(t) for t in self.fail_at))
        object.__setattr__(self, "fail_window",
                           tuple(int(t) for t in self.fail_window))
        object.__setattr__(self, "egress_window",
                           tuple(int(t) for t in self.egress_window))
        object.__setattr__(self, "slowdowns",
                           tuple((int(w[0]), int(w[1]), int(w[2]),
                                  float(w[3]))
                                 for w in self.slowdowns))
        assert len(self.fail_window) in (0, 2), \
            "fail_window is a (start_tick, end_tick) pair"
        assert len(self.egress_window) in (0, 2), \
            "egress_window is a (start_tick, end_tick) pair"
        assert 0.0 <= self.fail_prob <= 1.0
        assert 0.0 <= self.spike_prob <= 1.0
        assert 0.0 <= self.egress_prob <= 1.0
        assert all(len(w) == 3 for w in self.outages), \
            "outages are (start_tick, end_tick, shard) triples"
        assert all(len(w) == 4 and w[3] >= 0.0 for w in self.slowdowns), \
            "slowdowns are (start_tick, end_tick, shard, slow_us) 4-tuples"

    @property
    def active(self) -> bool:
        """True if any device-side *fetch* fault can ever fire (spikes and
        slowdowns are host-side only and do not perturb the compiled
        plan)."""
        return bool(self.fail_prob > 0.0 or self.outages or self.fail_at)

    @property
    def egress_active(self) -> bool:
        """True if any device-side *egress* (remote-write) fault can fire.
        Outages and ``fail_at`` ticks kill writes as well as fetches — an
        unreachable shard is unreachable in both directions."""
        return bool(self.egress_prob > 0.0 or self.outages or self.fail_at)

    # ---------------------------------------------------------- device ----
    def in_outage(self, tick, shard):
        """Traced bool []: is ``shard`` inside an outage window at ``tick``?"""
        tick = jnp.asarray(tick, jnp.int32)
        shard = jnp.asarray(shard, jnp.int32)
        hit = jnp.zeros((), bool)
        for start, end, sh in self.outages:  # static unroll (few windows)
            cover = (tick >= start) & (tick < end)
            if sh >= 0:
                cover = cover & (shard == sh)
            hit = hit | cover
        return hit

    def fetch_fail(self, tick, keys, shard=0):
        """Traced bool mask, shape of ``keys``: the remote fetch of each
        key fails at ``tick``.  Callers apply it only to entries that
        actually go remote (local hits never fault)."""
        keys = jnp.asarray(keys)
        fail = jnp.zeros(keys.shape, bool)
        if self.fail_prob > 0.0:
            salted = (keys.astype(jnp.uint32)
                      + jnp.asarray(shard).astype(jnp.uint32)
                      * jnp.uint32(_SHARD_SALT))
            fail = _u01(self.seed, tick, salted, jnp) < self.fail_prob
            if self.fail_window:
                w0, w1 = self.fail_window
                t = jnp.asarray(tick, jnp.int32)
                fail = fail & (t >= w0) & (t < w1)
        if self.outages:
            fail = fail | self.in_outage(tick, shard)
        if self.fail_at:
            at = jnp.asarray(self.fail_at, jnp.int32)
            fail = fail | jnp.any(at == jnp.asarray(tick, jnp.int32))
        return fail

    def egress_fail(self, tick, keys, shard=0):
        """Traced bool mask, shape of ``keys``: the remote *write* of each
        key fails at ``tick``.  Callers apply it at plan time to whole
        write units (a page writeback, an evacuation victim, a KV append)
        so a faulted write mutates neither tier (DESIGN.md §6c).  The
        stream is salted independently of :meth:`fetch_fail` — the same
        (tick, key) can fail one direction and not the other."""
        keys = jnp.asarray(keys)
        fail = jnp.zeros(keys.shape, bool)
        if self.egress_prob > 0.0:
            salted = (keys.astype(jnp.uint32)
                      ^ jnp.uint32(_EGRESS_SALT)) + (
                          jnp.asarray(shard).astype(jnp.uint32)
                          * jnp.uint32(_SHARD_SALT))
            fail = _u01(self.seed, tick, salted, jnp) < self.egress_prob
            if self.egress_window:
                w0, w1 = self.egress_window
                t = jnp.asarray(tick, jnp.int32)
                fail = fail & (t >= w0) & (t < w1)
        if self.outages:
            fail = fail | self.in_outage(tick, shard)
        if self.fail_at:
            at = jnp.asarray(self.fail_at, jnp.int32)
            fail = fail | jnp.any(at == jnp.asarray(tick, jnp.int32))
        return fail

    # ------------------------------------------------------------ host ----
    def fails(self, tick: int, key: int = 0, shard: int = 0) -> bool:
        """Host mirror of :meth:`fetch_fail` for a single (tick, key)."""
        if int(tick) in self.fail_at:
            return True
        for start, end, sh in self.outages:
            if start <= int(tick) < end and (sh < 0 or sh == int(shard)):
                return True
        if self.fail_prob > 0.0:
            if self.fail_window and not (
                    self.fail_window[0] <= int(tick) < self.fail_window[1]):
                return False
            salted = (np.uint32(np.int64(key) & 0xFFFFFFFF)
                      + np.uint32(shard) * np.uint32(_SHARD_SALT))
            return bool(_u01(self.seed, tick, salted, np) < self.fail_prob)
        return False

    def fails_egress(self, tick: int, key: int = 0, shard: int = 0) -> bool:
        """Host mirror of :meth:`egress_fail` for a single (tick, key)."""
        if int(tick) in self.fail_at:
            return True
        for start, end, sh in self.outages:
            if start <= int(tick) < end and (sh < 0 or sh == int(shard)):
                return True
        if self.egress_prob > 0.0:
            if self.egress_window and not (
                    self.egress_window[0] <= int(tick)
                    < self.egress_window[1]):
                return False
            with np.errstate(over="ignore"):
                salted = ((np.uint32(np.int64(key) & 0xFFFFFFFF)
                           ^ np.uint32(_EGRESS_SALT))
                          + np.uint32(shard) * np.uint32(_SHARD_SALT))
            return bool(_u01(self.seed, tick, salted, np) < self.egress_prob)
        return False

    def spike(self, tick: int) -> float:
        """Extra dispatch latency (us) injected at this tick; 0 if none."""
        if self.spike_prob <= 0.0:
            return 0.0
        if float(_u01(self.seed, tick, _SPIKE_KEY, np)) < self.spike_prob:
            return float(self.spike_us)
        return 0.0

    def slow_us(self, tick: int, shard: int = -1) -> float:
        """Extra latency (us) from slow-but-alive windows at this tick.

        ``shard == -1`` asks for the worst case over all shards — the
        right quantity for a collective exchange, where the slowest
        participant gates the whole tick.  Slowdowns are pure latency:
        they never appear in any failure predicate, so a slow shard must
        not trip the circuit breaker (slow ≠ dead)."""
        worst = 0.0
        for start, end, sh, us in self.slowdowns:
            if not (start <= int(tick) < end):
                continue
            if int(shard) >= 0 and sh >= 0 and sh != int(shard):
                continue
            worst = max(worst, us)
        return worst


NULL = Schedule()
