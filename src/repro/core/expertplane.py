"""Production tiered MoE expert store (kimi-k2 / mixtral decode).

Expert weights are far-memory-shaped state at decode time: a 384-expert
layer activates at most ``batch * topk`` experts per step, routing is
skewed, and the hot set churns — the paper's MCD-CL access pattern, with
experts as the unit of transfer.

Granularity note (DESIGN.md §Arch-applicability): an expert's FFN needs
*all* of its weights at once, so the object(card) granularity collapses to
the page granularity — each expert is one page.  The plane therefore runs
in pure-paging mode here (bulk expert DMA in, page-granular LRU eviction,
pinning of in-flight experts); the hybrid object path lives in the KV
plane where sub-page access is real.

The MoE math is computed directly against the *hot store*, indexed through
the expert->slot table (the smart-pointer indirection): compute cost scales
with the hot-set size, not the expert count.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class ExpertPlaneConfig:
    n_experts: int          # E
    d_model: int
    d_ff: int
    hot_slots: int          # S: experts resident in HBM
    topk: int
    fetch_budget: int = 8   # experts fetched per step
    capacity: int = 0       # tokens per slot buffer (0 -> derive)
    dtype: object = jnp.bfloat16
    # plan-then-execute fetch engine (mirrors KVPlaneConfig.fetch_mode):
    fetch_mode: str = "batch"   # "batch" (vectorized) | "reference" (scalar)
    kernel_impl: str = "auto"   # kernels.ops dispatch for the batched movers
    # fault model (repro.core.faults.Schedule; None == null schedule): a
    # faulted expert fetch is masked out of the plan (see plan_fetch)
    faults: object = None


class ExpertPlaneState(NamedTuple):
    # (the canonical far-tier expert weights stay in ``params`` — they are
    # passed to ensure_resident/moe_decode, not duplicated here)
    hot_wi: jnp.ndarray     # [S, d, f]
    hot_wg: jnp.ndarray     # [S, d, f]
    hot_wo: jnp.ndarray     # [S, f, d]
    slot_of: jnp.ndarray    # [E] int32 (-1 far)
    expert_of: jnp.ndarray  # [S] int32 (-1 free)
    clock: jnp.ndarray      # [S] int32
    access: jnp.ndarray     # [E] int32 activation counters (profiling)
    step: jnp.ndarray


def init(cfg: ExpertPlaneConfig) -> ExpertPlaneState:
    S, d, f = cfg.hot_slots, cfg.d_model, cfg.d_ff
    return ExpertPlaneState(
        hot_wi=jnp.zeros((S, d, f), cfg.dtype),
        hot_wg=jnp.zeros((S, d, f), cfg.dtype),
        hot_wo=jnp.zeros((S, f, d), cfg.dtype),
        slot_of=jnp.full((cfg.n_experts,), -1, jnp.int32),
        expert_of=jnp.full((S,), -1, jnp.int32),
        clock=jnp.zeros((S,), jnp.int32),
        access=jnp.zeros((cfg.n_experts,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


class ExpertFetchPlan(NamedTuple):
    """Fixed-shape ingress plan for one decode step: one entry per fetch
    budget slot."""
    expert: jnp.ndarray  # [budget] int32 expert to fetch (-1 = no-op)
    slot: jnp.ndarray    # [budget] int32 destination slot (distinct entries)


def plan_fetch(cfg: ExpertPlaneConfig, s: ExpertPlaneState,
               needed_mask: jnp.ndarray) -> ExpertFetchPlan:
    """One vectorized fetch plan: missing needed experts (up to
    ``fetch_budget``) paired with victim slots from a single masked top-k
    (slots hosting experts needed this step are pinned out)."""
    missing = jnp.logical_and(needed_mask, s.slot_of < 0)
    _, fetch_ids = lax.top_k(missing.astype(jnp.int32), cfg.fetch_budget)
    expert = jnp.where(missing[fetch_ids], fetch_ids, -1).astype(jnp.int32)

    # fault model (repro.core.faults): a faulted expert fetch drops out of
    # the plan HERE — the same plan-time masking as kvplane/batch — so it
    # never claims a slot or displaces a resident expert; its tokens are
    # dropped and re-normalized by moe_decode (graceful degradation).
    # Tick = s.step: moe_decode bumps the step BEFORE planning, where
    # kvplane plans pre-bump and keys step + 1 — both address the stream
    # entry of the step being decoded.
    fc = cfg.faults
    if fc is not None and fc.active:
        fail = (expert >= 0) & fc.fetch_fail(s.step, jnp.maximum(expert, 0))
        expert = jnp.where(fail, -1, expert)

    hosted_needed = jnp.where(s.expert_of >= 0,
                              needed_mask[jnp.maximum(s.expert_of, 0)], False)
    score = jnp.where(hosted_needed, jnp.iinfo(jnp.int32).max, s.clock)
    _, victims = lax.top_k(-score, cfg.fetch_budget)
    return ExpertFetchPlan(expert=expert, slot=victims)


def _exec_fetch_batch(cfg: ExpertPlaneConfig, s: ExpertPlaneState,
                      plan: ExpertFetchPlan, slab_wi, slab_wg, slab_wo
                      ) -> ExpertPlaneState:
    """Execute the plan with batched data movement: all expert weights
    arrive via one ``kernels.gather_rows`` call per tensor (each expert is
    one pool row — expert == page, DESIGN.md §Arch-applicability), and the
    hot-store insert is a leading-axis scatter.  Vectorization is safe
    because fetched experts are missing, displaced experts are resident
    (disjoint id sets) and victim slots are distinct."""
    E, S, d, f = cfg.n_experts, cfg.hot_slots, cfg.d_model, cfg.d_ff
    e, slot = plan.expert, plan.slot
    ok = e >= 0
    # invalid entries are dropped by the masked scatter below, so the
    # gathers skip the zero-fill pass
    safe_e = jnp.maximum(e, 0)
    wi = kops.gather_rows(slab_wi.reshape(E, d * f), safe_e,
                          impl=cfg.kernel_impl, masked=False).astype(cfg.dtype)
    wg = kops.gather_rows(slab_wg.reshape(E, d * f), safe_e,
                          impl=cfg.kernel_impl, masked=False).astype(cfg.dtype)
    wo = kops.gather_rows(slab_wo.reshape(E, f * d), safe_e,
                          impl=cfg.kernel_impl, masked=False).astype(cfg.dtype)

    sdst = jnp.where(ok, slot, S)                        # OOB scatter = drop
    old = s.expert_of[slot]
    slot_of = s.slot_of.at[jnp.where(ok & (old >= 0), old, E)].set(-1)
    return s._replace(
        hot_wi=s.hot_wi.reshape(S, d * f).at[sdst].set(wi).reshape(S, d, f),
        hot_wg=s.hot_wg.reshape(S, d * f).at[sdst].set(wg).reshape(S, d, f),
        hot_wo=s.hot_wo.reshape(S, f * d).at[sdst].set(wo).reshape(S, f, d),
        slot_of=slot_of.at[jnp.where(ok, e, E)].set(slot),
        expert_of=s.expert_of.at[sdst].set(e),
        clock=s.clock.at[sdst].set(s.step))


def _exec_fetch_reference(cfg: ExpertPlaneConfig, s: ExpertPlaneState,
                          plan: ExpertFetchPlan, slab_wi, slab_wg, slab_wo
                          ) -> ExpertPlaneState:
    """Scalar oracle: replay the identical plan one expert at a time (the
    seed-era fetch body driven by the shared plan)."""

    def fetch_one(i, s):
        e, slot = plan.expert[i], plan.slot[i]

        def do(s):
            old = s.expert_of[slot]
            s = lax.cond(
                old >= 0,
                lambda s: s._replace(slot_of=s.slot_of.at[old].set(-1)),
                lambda s: s, s)
            wi = lax.dynamic_index_in_dim(slab_wi, e, 0, keepdims=False
                                          ).astype(cfg.dtype)
            wg = lax.dynamic_index_in_dim(slab_wg, e, 0, keepdims=False
                                          ).astype(cfg.dtype)
            wo = lax.dynamic_index_in_dim(slab_wo, e, 0, keepdims=False
                                          ).astype(cfg.dtype)
            return s._replace(
                hot_wi=lax.dynamic_update_index_in_dim(s.hot_wi, wi, slot, 0),
                hot_wg=lax.dynamic_update_index_in_dim(s.hot_wg, wg, slot, 0),
                hot_wo=lax.dynamic_update_index_in_dim(s.hot_wo, wo, slot, 0),
                slot_of=s.slot_of.at[e].set(slot),
                expert_of=s.expert_of.at[slot].set(e),
                clock=s.clock.at[slot].set(s.step))

        return lax.cond(e >= 0, do, lambda s: s, s)

    return lax.fori_loop(0, cfg.fetch_budget, fetch_one, s)


def ensure_resident(cfg: ExpertPlaneConfig, s: ExpertPlaneState,
                    needed_mask: jnp.ndarray, slab_wi, slab_wg, slab_wo,
                    *, mode: str | None = None) -> ExpertPlaneState:
    """Fetch up to ``fetch_budget`` missing needed experts (plan-then-
    execute; victim slots = coldest experts not needed this step).  ``mode``
    selects the executor ("batch" | "reference", default
    ``cfg.fetch_mode``); both replay the identical plan."""
    mode = mode or cfg.fetch_mode
    if mode not in ("batch", "reference"):
        raise ValueError(f"unknown fetch mode: {mode!r}")
    plan = plan_fetch(cfg, s, needed_mask)
    if mode == "reference":
        return _exec_fetch_reference(cfg, s, plan, slab_wi, slab_wg, slab_wo)
    return _exec_fetch_batch(cfg, s, plan, slab_wi, slab_wg, slab_wo)


def moe_decode(cfg: ExpertPlaneConfig, s: ExpertPlaneState, router,
               x: jnp.ndarray, slab_wi, slab_wg, slab_wo,
               *, mode: str | None = None):
    """x: [T, d] decode-token activations; router: [d, E].
    Returns (y [T, d], state).  Tokens whose expert could not be made
    resident within the fetch budget are dropped for that expert (their
    gate weight is re-normalized away) — the bounded-staleness analogue of
    capacity dropping."""
    T, d = x.shape
    E, S, K = cfg.n_experts, cfg.hot_slots, cfg.topk
    C = cfg.capacity or max(8, -(-T * K * 2 // S))
    s = s._replace(step=s.step + 1)

    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, K)                    # [T, K]

    needed = jnp.zeros((E,), bool).at[expert.reshape(-1)].set(True)
    s = ensure_resident(cfg, s, needed, slab_wi, slab_wg, slab_wo, mode=mode)
    s = s._replace(access=s.access + needed.astype(jnp.int32),
                   clock=jnp.where(
                       jnp.where(s.expert_of >= 0,
                                 needed[jnp.maximum(s.expert_of, 0)], False),
                       s.step, s.clock))

    # dispatch by SLOT (smart-pointer indirection into the hot store)
    flat_e = expert.reshape(-1)
    slot = s.slot_of[flat_e]                              # [T*K] (-1 dropped)
    sort_idx = jnp.argsort(jnp.where(slot >= 0, slot, S))
    sorted_slot = jnp.where(slot[sort_idx] >= 0, slot[sort_idx], S)
    pos = jnp.arange(T * K, dtype=jnp.int32)
    seg_start = jnp.full((S + 1,), T * K, jnp.int32).at[sorted_slot].min(pos)
    rank_sorted = pos - seg_start[sorted_slot]
    rank = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(rank_sorted)
    keep = jnp.logical_and(slot >= 0, rank < C)
    dst = jnp.where(keep, slot * C + rank, S * C)

    xe = jnp.zeros((S * C + 1, d), cfg.dtype)
    src_tok = jnp.repeat(jnp.arange(T), K)
    xe = xe.at[dst].set(x[src_tok].astype(cfg.dtype))
    xe = xe[:-1].reshape(S, C, d)

    g = jnp.einsum("scd,sdf->scf", xe, s.hot_wg,
                   preferred_element_type=jnp.float32)
    i = jnp.einsum("scd,sdf->scf", xe, s.hot_wi,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * i).astype(cfg.dtype)
    ye = jnp.einsum("scf,sfd->scd", h, s.hot_wo,
                    preferred_element_type=jnp.float32).astype(cfg.dtype)
    ye = jnp.concatenate([ye.reshape(S * C, d),
                          jnp.zeros((1, d), cfg.dtype)], axis=0)

    yt = ye[dst].reshape(T, K, d).astype(jnp.float32)
    w = jnp.where(keep.reshape(T, K), gate, 0.0)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    y = jnp.einsum("tkd,tk->td", yt, w)
    return y.astype(x.dtype), s


# --------------------------------------------------------------------------
# memoized serve-path jit entry points (state-donating)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_moe_decode(cfg: ExpertPlaneConfig, mode: str):
    return jax.jit(functools.partial(moe_decode, cfg, mode=mode),
                   donate_argnums=(0,))


def jitted_moe_decode(cfg: ExpertPlaneConfig, mode: str | None = None):
    return _jitted_moe_decode(cfg, mode or cfg.fetch_mode)


@functools.lru_cache(maxsize=None)
def _jitted_ensure_resident(cfg: ExpertPlaneConfig, mode: str):
    return jax.jit(functools.partial(ensure_resident, cfg, mode=mode),
                   donate_argnums=(0,))


def jitted_ensure_resident(cfg: ExpertPlaneConfig, mode: str | None = None):
    return _jitted_ensure_resident(cfg, mode or cfg.fetch_mode)
