"""Baseline data planes, per the paper's evaluation (§5.1 "Baselines").

* ``paging_access``  — Fastswap analogue: page-granular ingress **and**
  egress, kernel-style sequential readahead, no object machinery at all.
  Resource-cheap (victim selection is O(frames)) but suffers I/O
  amplification on sparse access.

* ``object_access``  — AIFM analogue: object-granular ingress **and**
  egress.  Maintains a true object-level LRU (per-object timestamps) and on
  memory pressure scans it to evict the coldest objects individually,
  scattering them into a remote log.  ``lru_scan_budget`` models the
  CPU-starved regime from the paper (scan a bounded window -> evict
  near-arbitrary objects -> thrashing).

Both ingress paths run on the plan-then-execute batch engine
(:mod:`repro.core.batch`) so all three planes share the same data movers
and the benchmarks compare pure policy differences; the object plane's
LRU egress loop below stays scalar because the paper's point is exactly
that object-granular egress serializes on metadata scans.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import batch as batch_lib
from . import paths
from . import state as st
from .layout import FREE, LOCAL, REMOTE, PlaneConfig

INF32 = jnp.iinfo(jnp.int32).max


# --------------------------------------------------------------------------
# Fastswap analogue
# --------------------------------------------------------------------------

def paging_access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                  *, mode: str | None = None, shard=None,
                  degraded: bool = False):
    """Page-granular plane: every miss pages in (with readahead); no CAT,
    no PSF consultation, no object moves.  Egress is the shared page-out."""
    return batch_lib.paging_access(cfg, s, obj_ids, mode=mode, shard=shard,
                                   degraded=degraded)


# --------------------------------------------------------------------------
# AIFM analogue
# --------------------------------------------------------------------------

def _object_out_coldest(cfg: PlaneConfig, s: st.PlaneState) -> st.PlaneState:
    """Evict one object chosen by the object-level LRU.

    Full scan: argmin of per-object last-access among local objects — the
    O(num_objs) cost the paper charges object planes for.  With
    ``lru_scan_budget > 0`` only a rotating window is scanned (CPU-starved
    regime -> near-arbitrary victims)."""
    O = cfg.num_objs
    vp = s.obj_loc // cfg.page_objs
    local = (s.obj_loc >= 0) & (s.backing[jnp.clip(vp, 0, cfg.num_vpages - 1)] == LOCAL)
    unpinned = s.pin[jnp.clip(vp, 0, cfg.num_vpages - 1)] == 0

    if cfg.lru_scan_budget and cfg.lru_scan_budget < O:
        B = cfg.lru_scan_budget
        idx = (s.lru_hand + jnp.arange(B)) % O
        cand_mask = local[idx] & unpinned[idx]
        score = jnp.where(cand_mask, s.obj_last[idx], INF32)
        o = idx[jnp.argmin(score)]
        scanned = B
        s = s._replace(lru_hand=(s.lru_hand + B) % O)
        valid = jnp.any(cand_mask)
    else:
        score = jnp.where(local & unpinned, s.obj_last, INF32)
        o = jnp.argmin(score).astype(jnp.int32)
        scanned = O
        valid = jnp.any(local & unpinned)

    def evict(s):
        va = s.obj_loc[o]
        v, slot = va // cfg.page_objs, va % cfg.page_objs
        row = s.frames[s.frame_of[v], slot]
        s = _append_obj_remote(cfg, s, o, row)
        return s._replace(stats=st.bump(s.stats, obj_outs=1))

    s = s._replace(stats=st.bump(s.stats, lru_scans=scanned))
    return lax.cond(valid, evict, lambda s: s, s)


def _append_obj_remote(cfg: PlaneConfig, s: st.PlaneState, o, row) -> st.PlaneState:
    """Move object ``o`` to the remote log (object-granular egress).

    Objects evicted at different times land on unrelated remote pages —
    the locality-disruption effect the paper attributes to object egress."""

    def need_new(s):
        cur = s.remote_fill_vpage
        return jnp.logical_or(
            cur < 0, s.alloc_count[jnp.maximum(cur, 0)] >= cfg.page_objs)

    def alloc_remote_log(s):
        cur = s.remote_fill_vpage
        s = lax.cond(cur >= 0, lambda s: paths.unpin_page(s, cur), lambda s: s, s)
        v = jnp.argmax(s.backing == FREE).astype(jnp.int32)
        s = s._replace(
            backing=s.backing.at[v].set(REMOTE),
            alloc_count=s.alloc_count.at[v].set(0),
            live_count=s.live_count.at[v].set(0),
            obj_of=s.obj_of.at[v].set(-1),
            car_ema=s.car_ema.at[v].set(0.0),   # fresh page identity
            remote_fill_vpage=v,
        )
        return paths.pin_page(s, v)

    s = lax.cond(need_new(s), alloc_remote_log, lambda s: s, s)
    v_new = s.remote_fill_vpage
    slot_new = s.alloc_count[v_new]

    old = s.obj_loc[o]
    v_old, slot_old = old // cfg.page_objs, old % cfg.page_objs

    s = s._replace(
        slab=s.slab.at[v_new, slot_new].set(row),
        obj_loc=s.obj_loc.at[o].set(v_new * cfg.page_objs + slot_new),
        obj_of=s.obj_of.at[v_new, slot_new].set(o),
        alloc_count=s.alloc_count.at[v_new].add(1),
        live_count=s.live_count.at[v_new].add(1),
    )
    return paths._kill_old_copy(cfg, s, v_old, slot_old)


def object_reclaim(cfg: PlaneConfig, s: st.PlaneState, target_free: int
                   ) -> st.PlaneState:
    """Evict coldest objects until ``target_free`` frames are free (the
    object plane's egress loop; bounded by the live-object count)."""

    def free_frames(s):
        return jnp.sum((s.vpage_of < 0).astype(jnp.int32))

    def cond(s):
        return free_frames(s) < target_free

    def body(s):
        s0_outs = s.stats.obj_outs

        def one(k, s):
            return _object_out_coldest(cfg, s)

        s = lax.fori_loop(0, cfg.object_evict_batch, one, s)
        # no progress (everything pinned) -> bail by faking success
        stuck = s.stats.obj_outs == s0_outs
        return lax.cond(stuck, lambda s: s, lambda s: s, s)

    # hard bound: each iteration evicts object_evict_batch objects
    max_iter = (cfg.num_objs // max(cfg.object_evict_batch, 1)) + 2

    def bounded_cond(carry):
        s, it = carry
        return jnp.logical_and(cond(s), it < max_iter)

    def bounded_body(carry):
        s, it = carry
        return body(s), it + 1

    s, _ = lax.while_loop(bounded_cond, bounded_body,
                          (s, jnp.asarray(0, jnp.int32)))
    return s


def object_access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                  reclaim_free_target: int = 2, *, mode: str | None = None,
                  shard=None, degraded: bool = False):
    """Object-granular plane (AIFM analogue): every miss object-fetches;
    after the batch, reclaim via the object-level LRU if frames are tight."""
    return batch_lib.object_access(cfg, s, obj_ids, reclaim_free_target,
                                   mode=mode, reclaim=object_reclaim,
                                   shard=shard, degraded=degraded)


# memoized jit entry points (one compilation per config per process — see
# plane.jitted_access; wrappers normalize ``mode`` before the cache lookup)

@functools.lru_cache(maxsize=None)
def _jitted_paging_access(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(paging_access, cfg, mode=mode))


def jitted_paging_access(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_paging_access(cfg, mode or cfg.access_mode)


@functools.lru_cache(maxsize=None)
def _jitted_object_access(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(object_access, cfg, mode=mode))


def jitted_object_access(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_object_access(cfg, mode or cfg.access_mode)


# plan/execute split entry points (pipelined serving dispatch — the plan of
# batch N+1 is enqueued while batch N's execute runs; see serving.engine)

@functools.lru_cache(maxsize=None)
def _jitted_plan_paging(cfg: PlaneConfig, degraded: bool):
    return jax.jit(partial(batch_lib.plan_access, cfg, split_by_psf=False,
                           degraded=degraded))


def jitted_plan_paging(cfg: PlaneConfig, degraded: bool = False):
    return _jitted_plan_paging(cfg, degraded)


@functools.lru_cache(maxsize=None)
def _jitted_execute_paging(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(batch_lib.execute_paging_access, cfg, mode=mode))


def jitted_execute_paging(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_execute_paging(cfg, mode or cfg.access_mode)


@functools.lru_cache(maxsize=None)
def _jitted_plan_object(cfg: PlaneConfig, degraded: bool):
    return jax.jit(partial(batch_lib.plan_access, cfg, all_runtime=True,
                           degraded=degraded))


def jitted_plan_object(cfg: PlaneConfig, degraded: bool = False):
    return _jitted_plan_object(cfg, degraded)


@functools.lru_cache(maxsize=None)
def _jitted_execute_object(cfg: PlaneConfig, mode: str):
    return jax.jit(partial(batch_lib.execute_object_access, cfg, mode=mode,
                           reclaim=object_reclaim))


def jitted_execute_object(cfg: PlaneConfig, mode: str | None = None):
    return _jitted_execute_object(cfg, mode or cfg.access_mode)
