"""Plan-then-execute batch ingress engine (the production access path).

The scalar loop in the original ``plane.access`` threaded the whole plane
state through one ``lax.fori_loop`` iteration *per request*, serializing
every dereference and never touching the batched Pallas kernels.  This
module replaces it with a three-stage engine:

  1. **Plan** (vectorized over the batch): gather ``obj_loc``, classify each
     request hit/miss against the batch-entry state, split misses by the
     page's PSF, and dedup — paging misses per *page*, runtime misses per
     *object* — in first-appearance order (sort/unique-style masking).
     The paging plan then grows a **prefetch-candidate section** (Leap-style
     majority-vote stride detection over the deduped miss stream, or the
     seed sequential window — ``cfg.prefetch``), deduped, PSF-masked and
     capped by the static ``cfg.prefetch_budget``, and every planned fetch
     (demand + prefetch) is paired with an eviction **victim frame** chosen
     in one masked top-k over the frame pool (free frames first, then
     coldest unpinned; frames holding this batch's target pages only under
     extreme pressure; prefetches never evict a target).
  2. **Execute** (all vectorized):
       * *paging plan*  — every page-out as masked scatters (write-back,
         PSF-from-CAR, CAT clear) and every page-in — demand and prefetch
         alike — in ONE batched ``kernels.gather_pages`` call; no
         per-victim ``fori_loop``/``cond`` chain,
       * *runtime plan* — fill-page capacity is computed with prefix
         arithmetic, fresh log pages are allocated up front, and the rows
         themselves move in ONE batched ``kernels.gather_rows`` +
         scatter — no per-object append chains.
  3. **Finish** (vectorized): CAT/access-bit/clock/obj_last profiling is
     applied in a single ``cat_update``-style scatter pass, and results are
     read with one batched gather over the final locations.

Batch semantics (shared by both executors, see DESIGN.md §Batch ingress):
classification happens once against batch-entry state; duplicate requests
for an already-scheduled page/object count as hits; a page evicted
mid-batch under extreme memory pressure is *not* re-faulted — the final
gather falls back to its (written-back) slab copy, so results are always
ground truth.  A **negative object id is a padded no-op request**: it
classifies as neither hit nor miss, moves and profiles nothing (all its
scatter indices are out-of-bounds sentinels, which JAX drops), and its
result row is zero — the fixed-shape padding mechanism used by the
sharded exchange (repro.core.shardplane) and partially-filled batches.

``mode="reference"`` runs the same plan through a scalar executor (one
state update per moved row / touched card, using the ``paths`` helpers) —
the oracle the equivalence tests compare the batched executor against,
byte-for-byte.

The kernel dispatch (``PlaneConfig.kernel_impl``) follows ``kernels.ops``:
``"auto"`` uses Pallas on TPU and the jnp reference elsewhere;
``"interpret"`` runs the Pallas kernel bodies in interpret mode so CPU CI
exercises the real kernel code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from ..kernels import ops as kops
from . import paths
from . import state as st
from .layout import FREE, LOCAL, REMOTE, PlaneConfig

INF32 = jnp.iinfo(jnp.int32).max


# --------------------------------------------------------------------------
# planning primitives (vectorized dedup / classification)
# --------------------------------------------------------------------------

def _first_of(keys: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """First-appearance flags: ``out[i]`` is True iff ``mask[i]`` and no
    ``j < i`` has ``mask[j] and keys[j] == keys[i]``.  O(R^2) compare —
    trivial for serving-batch sizes and fully parallel."""
    R = keys.shape[0]
    i = jnp.arange(R, dtype=jnp.int32)
    same = (keys[None, :] == keys[:, None]) & mask[None, :]
    first_j = jnp.min(jnp.where(same, i[None, :], R), axis=1)
    return mask & (first_j == i)


def _compact(keys: jnp.ndarray, first: jnp.ndarray):
    """Pack the flagged keys to the front (first-appearance order).
    Returns (plan [R] int32 with -1 padding, count)."""
    R = keys.shape[0]
    pos = jnp.cumsum(first.astype(jnp.int32)) - 1
    idx = jnp.where(first, pos, R)            # R = out of bounds -> dropped
    plan = jnp.full((R,), -1, jnp.int32).at[idx].set(keys)
    return plan, jnp.sum(first.astype(jnp.int32))


def majority_stride(d: jnp.ndarray, n_d: jnp.ndarray):
    """Leap-style majority vote over the first ``n_d`` entries of the delta
    sequence ``d``: the dominant delta wins an absolute majority, else the
    most recent delta is the fallback (as in Leap).  Returns
    ``(stride, have)`` — ``have`` is False when there is no usable trend
    (no deltas, or a zero stride).  Shared by the core paging planner and
    the kvplane decode lookahead."""
    N = d.shape[0]
    dvalid = jnp.arange(N) < n_d
    same = (d[None, :] == d[:, None]) & dvalid[None, :]
    counts = jnp.where(dvalid, jnp.sum(same.astype(jnp.int32), axis=1), 0)
    best = jnp.argmax(counts).astype(jnp.int32)
    majority = counts[best] * 2 > n_d
    last = d[jnp.clip(n_d - 1, 0, N - 1)]
    stride = jnp.where(majority, d[best], last)
    return stride, (n_d >= 1) & (stride != 0)


class AccessPlan(NamedTuple):
    """Fixed-shape pytree describing one batch's ingress work.  Because the
    shapes depend only on the batch size (and the static prefetch budget),
    a sharded plane can compute the next batch's plan on host while the
    previous one executes.

    The paging section is fully resolved at plan time: ``pg_fetch`` lists
    every page-in to perform — the deduped demand misses followed by the
    prefetch-candidate section — and ``pg_victim`` pairs each with the
    frame it lands in (chosen by one masked top-k over the pool; a fetch
    with no usable victim is dropped to ``-1``).  The executors never make
    another eviction decision."""

    vpage: jnp.ndarray      # [R] entry vpages (soft-pin / recency targets)
    page_plan: jnp.ndarray  # [R] deduped paging-miss pages (-1 pad)
    n_pages: jnp.ndarray    # [] number of valid entries in page_plan
    obj_plan: jnp.ndarray   # [R] deduped runtime-miss objects (-1 pad)
    n_objs: jnp.ndarray     # [] number of valid entries in obj_plan
    pg_fetch: jnp.ndarray   # [R+Q] scheduled page-ins, demand++prefetch (-1)
    pg_victim: jnp.ndarray  # [R+Q] destination frame per scheduled fetch
    pg_is_pf: jnp.ndarray   # [R+Q] bool: entry belongs to the prefetch section
    # Fault-model section (repro.core.faults).  With no (or a null)
    # schedule: served == (obj_ids >= 0), n_miss == n_pages + n_objs and
    # n_failed == n_egress == 0 — every consumer below reduces to the
    # fault-free math.
    served: jnp.ndarray     # [R] bool: request's row is ground truth this tick
    n_miss: jnp.ndarray     # [] classified misses (pre-fault; stats basis)
    n_failed: jnp.ndarray   # [] planned fetches masked off by the fault model
    n_egress: jnp.ndarray   # [] remote writes blocked by the fault model
    #                         (eviction writebacks dropped at victim planning
    #                         + remote update writes masked when for_update)


def _prefetch_candidates(cfg: PlaneConfig, s: st.PlaneState,
                         page_plan: jnp.ndarray, n_pages: jnp.ndarray,
                         *, use_psf: bool) -> jnp.ndarray:
    """Build the prefetch-candidate section of the paging plan: ``[Q]``
    pages (-1 pad), deduped, bounds/backing checked, PSF-masked (hybrid
    only) and disjoint from the demand plan.

    ``prefetch="sequential"`` is the seed readahead policy in plan form:
    each demand miss contributes its following ``cfg.readahead`` pages, in
    (miss order, offset) priority.  ``prefetch="majority"`` is the
    Leap-style detector: a majority vote over the deltas of the deduped
    miss stream picks the dominant stride (falling back to the most recent
    delta when no majority exists, as in Leap), and candidates extrapolate
    that trend from the last miss."""
    V, Q, R = cfg.num_vpages, cfg.prefetch_budget, page_plan.shape[0]
    none = jnp.full((Q,), -1, jnp.int32)
    if cfg.prefetch == "sequential":
        if cfg.readahead <= 0:
            return none
        off = jnp.arange(1, cfg.readahead + 1, dtype=jnp.int32)
        cand = jnp.where(page_plan[:, None] >= 0,
                         page_plan[:, None] + off[None, :], -1).reshape(-1)
    else:  # "majority"
        if R < 2:
            return none
        stride, have = majority_stride(page_plan[1:] - page_plan[:-1],
                                       jnp.maximum(n_pages - 1, 0))
        base = page_plan[jnp.clip(n_pages - 1, 0, R - 1)]
        k = jnp.arange(1, Q + 1, dtype=jnp.int32)
        cand = jnp.where(have, base + k * stride, -1)
    ok = (cand >= 0) & (cand < V)
    safe = jnp.clip(cand, 0, V - 1)
    ok &= s.backing[safe] == REMOTE          # allocated and currently far
    if use_psf:
        ok &= s.psf[safe]                    # only paging-path pages
    ok &= ~jnp.any(cand[:, None] == page_plan[None, :], axis=1)
    cand = jnp.where(ok, cand, -1)
    plan, _ = _compact(cand, _first_of(cand, ok))
    return plan[:Q]


def _plan_victims(cfg: PlaneConfig, s: st.PlaneState, req_v: jnp.ndarray,
                  fetch: jnp.ndarray, is_pf: jnp.ndarray):
    """Pair every scheduled fetch with a destination frame in ONE masked
    top-k over the frame pool: free frames first (index order), then the
    coldest unpinned occupied frames by entry clock.  Frames holding this
    batch's target pages rank last (the soft-pin: evicted only under
    extreme pressure, and never for a prefetch); pinned frames never.
    Fetches beyond the usable pool are dropped (-1) — prefetches first,
    since the demand section precedes them in rank order."""
    F, V = cfg.num_frames, cfg.num_vpages
    N = fetch.shape[0]
    occ = s.vpage_of >= 0
    vres = jnp.maximum(s.vpage_of, 0)
    pinned = occ & (s.pin[vres] > 0)
    target = jnp.zeros((V,), bool).at[req_v].set(True)
    is_tgt = occ & target[vres]
    score = jnp.where(~occ, -INF32,
                      jnp.where(pinned, INF32,
                                jnp.where(is_tgt, INF32 - 1, s.clock[vres])))
    k = min(N, F)
    neg, victims = lax.top_k(-score, k)          # ascending-score frames
    vic_score = -neg
    ok = fetch >= 0
    rank = jnp.cumsum(ok.astype(jnp.int32)) - 1
    r = jnp.clip(rank, 0, k - 1)
    vs = vic_score[r]
    usable = ok & (rank < k) & (vs < INF32) & (~is_pf | (vs < INF32 - 1))
    return (jnp.where(usable, fetch, -1),
            jnp.where(usable, victims[r], -1))


def plan_access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                *, split_by_psf: bool = True, all_runtime: bool = False,
                degraded=False, for_update: bool = False,
                shard=None) -> AccessPlan:
    """Classify the batch and build the two ingress plans (plus the paging
    plan's prefetch section and victim assignment).

    Shape contract: ``obj_ids`` is ``[R]`` int32 (negative = padded no-op
    request); the returned :class:`AccessPlan` is the fixed-shape pytree
    above, every field a function of ``(cfg, state, obj_ids)`` only.
    Owned by DESIGN.md §3 (plan/execute split) and §6/§6c (fault masking).

    ``split_by_psf=False`` sends every miss down the paging plan (Fastswap
    baseline; its prefetch section skips the PSF mask — no PSF
    consultation is the point); ``all_runtime=True`` sends every miss down
    the runtime plan (AIFM baseline; no paging section at all).

    When ``cfg.faults`` is an active schedule, each planned remote fetch
    is additionally masked by ``faults.fetch_fail(step+1, vpage, shard)``
    — a faulted fetch becomes a ``-1`` no-op plan entry (the PR-4 padding
    convention), the requests that depended on it come back with
    ``served=False``, and ``n_failed`` counts the masked fetches.  Because
    the mask is applied at *plan* time, a faulted fetch never moves a
    byte: no victim is paged out for it and no frame is partially
    written.

    Egress faults apply the same plan-time discipline to remote *writes*
    (DESIGN.md §6c): a scheduled page-in whose victim frame holds a page
    that cannot be written back is dropped (fetch and victim to ``-1``,
    demand drops counted in ``n_egress``) — the occupant stays local, the
    requester still serves from the slab copy, nothing is lost.  With
    ``for_update=True`` (the write path), requests predicted to remain
    remote at execute time are additionally masked ``served=False`` when
    their slab write would fault, so ``execute_update`` mutates neither
    tier for them.

    ``degraded`` (the engine's circuit-breaker mode) suppresses every
    remote fetch instead — the plane serves local hits only, without
    charging ``fetch_failures``.  It accepts a static Python bool (one
    compiled program per mode) or a traced scalar bool (the sharded
    per-shard breaker passes each shard its own flag through one shared
    program); both produce bit-identical plans."""
    R = obj_ids.shape[0]
    Q = cfg.prefetch_budget
    # A negative id is a padded no-op request (the sharded exchange and any
    # partially-filled batch use this): it misses nothing, touches nothing,
    # and its result row is zero.  Sentinel indices (V for vpages) make its
    # scatters drop and keep every shape static.
    valid = obj_ids >= 0
    vaddr = s.obj_loc[jnp.maximum(obj_ids, 0)]
    v = vaddr // cfg.page_objs
    local = s.backing[v] == LOCAL
    if all_runtime:
        pg_mask = jnp.zeros_like(local)
        rt_mask = valid & ~local
    elif split_by_psf:
        psf = s.psf[v]
        pg_mask = valid & ~local & psf
        rt_mask = valid & ~local & ~psf
    else:
        pg_mask = valid & ~local
        rt_mask = jnp.zeros_like(local)
    v = jnp.where(valid, v, cfg.num_vpages)
    page_plan, n_pages = _compact(v, _first_of(v, pg_mask))
    obj_plan, n_objs = _compact(obj_ids, _first_of(obj_ids, rt_mask))
    # Capacity governor for the runtime plan: fresh log pages allocate with
    # pin-masked LRU eviction, so when standing pins (allocation cursors)
    # occupy almost the whole pool, an unbounded move list could force the
    # allocator to evict a pinned cursor with appends still pending — the
    # corruption the seed's "callers bound pins per batch" note waved away.
    # Cap the moves so every fresh-page allocation still finds an unpinned
    # victim; excess miss objects simply stay remote this batch (the final
    # gather serves them from the slab — results stay ground truth).
    occ_f = s.vpage_of >= 0
    pinned_frames = jnp.sum(
        (occ_f & (s.pin[jnp.maximum(s.vpage_of, 0)] > 0)).astype(jnp.int32))
    fill = s.fill_vpage
    free_slots = jnp.where(fill >= 0,
                           cfg.page_objs
                           - s.alloc_count[jnp.maximum(fill, 0)], 0)
    cap = free_slots + cfg.page_objs * jnp.maximum(
        cfg.num_frames - pinned_frames, 0)
    n_objs = jnp.minimum(n_objs, cap)
    obj_plan = jnp.where(jnp.arange(R) < n_objs, obj_plan, -1)
    if all_runtime:
        pf_plan = jnp.full((Q,), -1, jnp.int32)
    else:
        # candidates come from the *unmasked* compacted demand plan (the
        # stride vote reads its deltas); fault masking happens below
        pf_plan = _prefetch_candidates(cfg, s, page_plan, n_pages,
                                       use_psf=split_by_psf)
    # classified misses, before any fault masking: the stats basis (a
    # faulted request still missed — it just isn't served this tick)
    n_miss = n_pages + n_objs
    served = valid
    n_failed = jnp.zeros((), jnp.int32)
    n_egress = jnp.zeros((), jnp.int32)
    fc = cfg.faults
    tick = s.step + 1                        # the step this batch executes at
    shard_i = 0 if shard is None else shard
    static_deg = isinstance(degraded, bool)
    if static_deg and degraded:
        # circuit-breaker mode: attempt no remote fetch at all (demand,
        # object or speculative) — local hits are the whole service
        page_plan = jnp.full((R,), -1, jnp.int32)
        n_pages = jnp.zeros((), jnp.int32)
        obj_plan = jnp.full((R,), -1, jnp.int32)
        n_objs = jnp.zeros((), jnp.int32)
        pf_plan = jnp.full((Q,), -1, jnp.int32)
        served = valid & local
        egress_on = False                    # no remote write can be planned
    else:
        if fc is not None and fc.active:
            # demand paging plan: faulted entries hole out to -1 (the
            # executors' `fetch >= 0` masks drop holes without re-compaction)
            failp = (page_plan >= 0) & fc.fetch_fail(tick, page_plan, shard_i)
            n_failed_p = jnp.sum(failp.astype(jnp.int32))
            page_plan = jnp.where(failp, -1, page_plan)
            n_pages = n_pages - n_failed_p
            # speculative fetches fault too, but silently (not a failure: no
            # request depended on them)
            failq = (pf_plan >= 0) & fc.fetch_fail(tick, pf_plan, shard_i)
            pf_plan = jnp.where(failq, -1, pf_plan)
            # runtime plan: mask, then RE-compact — _exec_runtime assigns
            # append slots positionally (`t < n_move`), so holes are not
            # allowed
            v_obj = s.obj_loc[jnp.maximum(obj_plan, 0)] // cfg.page_objs
            failo = (obj_plan >= 0) & fc.fetch_fail(tick, v_obj, shard_i)
            n_failed_o = jnp.sum(failo.astype(jnp.int32))
            keep = (obj_plan >= 0) & ~failo
            obj_plan, n_objs = _compact(jnp.where(keep, obj_plan, -1), keep)
            # a request is served unless its (remote) page's fetch faulted;
            # capacity-capped and victim-starved requests still serve from
            # the written-back slab copy (memory pressure, not a fault)
            served = valid & (local | ~fc.fetch_fail(tick, v, shard_i))
            n_failed = n_failed_p + n_failed_o
        if not static_deg:
            # traced circuit-breaker flag (the sharded per-shard breaker):
            # emulate the static degraded branch with where-overrides so one
            # compiled program serves degraded and healthy shards alike,
            # bit-identically to the static branch per shard
            deg = jnp.asarray(degraded, bool)
            page_plan = jnp.where(deg, -1, page_plan)
            n_pages = jnp.where(deg, 0, n_pages)
            obj_plan = jnp.where(deg, -1, obj_plan)
            n_objs = jnp.where(deg, 0, n_objs)
            pf_plan = jnp.where(deg, -1, pf_plan)
            served = jnp.where(deg, valid & local, served)
            n_failed = jnp.where(deg, 0, n_failed)
        egress_on = fc is not None and fc.egress_active
    fetch = jnp.concatenate([page_plan, pf_plan])
    is_pf = jnp.concatenate([jnp.zeros((R,), bool), jnp.ones((Q,), bool)])
    fetch, victim = _plan_victims(cfg, s, v, fetch, is_pf)
    if egress_on:
        # egress side (DESIGN.md §6c): a scheduled page-in whose victim
        # frame holds a page that cannot be written back this tick is
        # dropped whole — the occupant stays local (no data loss), the
        # requester still serves from the slab copy.  Keyed by the
        # *occupant* vpage: the write that would fail is its writeback.
        old_v = s.vpage_of[jnp.maximum(victim, 0)]
        evicting = (victim >= 0) & (old_v >= 0)
        efail = evicting & fc.egress_fail(tick, jnp.maximum(old_v, 0),
                                          shard_i)
        n_egress = jnp.sum((efail & ~is_pf).astype(jnp.int32))
        fetch = jnp.where(efail, -1, fetch)
        victim = jnp.where(efail, -1, victim)
        if for_update:
            # the write path: a request predicted to remain remote at
            # execute time writes the slab — mask it unserved when that
            # write would fault, so execute_update touches nothing for it
            # (conservative prediction: extreme-pressure mid-batch
            # evictions can only flip a predicted-local entry to an
            # unmasked slab write, which stays correct, just unfaulted)
            will_local = local | jnp.any(
                (fetch[None, :] == v[:, None]) & (victim[None, :] >= 0),
                axis=1)
            moved = jnp.any((obj_plan[None, :] == obj_ids[:, None])
                            & (obj_plan[None, :] >= 0), axis=1)
            wfail = (served & ~will_local & ~moved
                     & fc.egress_fail(tick, v, shard_i))
            served = served & ~wfail
            n_egress = n_egress + jnp.sum(wfail.astype(jnp.int32))
    return AccessPlan(v, page_plan, n_pages, obj_plan, n_objs,
                      fetch, victim, is_pf, served, n_miss, n_failed,
                      n_egress)


# --------------------------------------------------------------------------
# execution: paging plan
# --------------------------------------------------------------------------

def _exec_paging(cfg: PlaneConfig, s: st.PlaneState, plan: AccessPlan, *,
                 scalar: bool) -> st.PlaneState:
    """Execute the planned page-ins (demand + prefetch).

    The batched executor performs every page-out as masked scatters
    (write-back, PSF-from-CAR, CAT clear) and every page-in with ONE
    ``kernels.gather_pages`` call over the slab's page view, then one
    frame-pool scatter — no per-victim ``fori_loop``/``cond`` chain.  Safe
    because the plan's touched sets are disjoint: victims are distinct
    frames (top-k), evicted pages are currently resident, fetched pages
    are currently remote.  The scalar executor replays the identical plan
    one fetch at a time through the ``paths`` helpers — the equivalence
    oracle, bit-identical by the same disjointness."""
    P, V, F, D = cfg.page_objs, cfg.num_vpages, cfg.num_frames, cfg.obj_dim
    fetch, vic, is_pf = plan.pg_fetch, plan.pg_victim, plan.pg_is_pf
    N = fetch.shape[0]
    ok = fetch >= 0

    if scalar:
        def body(j, s):
            def do(s):
                f = vic[j]
                s = lax.cond(s.vpage_of[f] >= 0,
                             lambda s: paths.page_out(cfg, s, f),
                             lambda s: s, s)
                s = paths.page_in_at(cfg, s, fetch[j], f)

                def mark(s):
                    return s._replace(
                        prefetched=s.prefetched.at[fetch[j]].set(True),
                        stats=st.bump(s.stats, prefetch_issued=1))

                return lax.cond(is_pf[j], mark, lambda s: s, s)

            return lax.cond(ok[j], do, lambda s: s, s)

        return lax.fori_loop(0, N, body, s)

    # ---- page-out: masked scatters over the distinct victim set ---------
    vf = jnp.maximum(vic, 0)
    old_v = jnp.where(ok, s.vpage_of[vf], -1)
    evict = ok & (old_v >= 0)
    ovs = jnp.maximum(old_v, 0)
    ov = jnp.where(evict, old_v, V)              # OOB scatter index = drop
    car_inst = (jnp.sum(s.cat[ovs].astype(jnp.int32), axis=1).astype(
        jnp.float32) / jnp.maximum(s.alloc_count[ovs], 1).astype(jnp.float32))
    car = jnp.maximum(car_inst, s.car_ema[ovs])  # EMA blend (see paths.page_out)
    new_psf = car >= s.car_thr
    old_psf = s.psf[ovs]
    flip_p = jnp.sum((evict & ~old_psf & new_psf).astype(jnp.int32))
    flip_r = jnp.sum((evict & old_psf & ~new_psf).astype(jnp.int32))
    n_dirty = jnp.sum((evict & s.dirty[ovs]).astype(jnp.int32))
    slab = s.slab.at[ov].set(s.frames[vf])       # unconditional write-back
    psf = s.psf.at[ov].set(new_psf)
    cat = s.cat.at[ov].set(False)
    backing = s.backing.at[ov].set(jnp.int8(REMOTE))
    frame_of = s.frame_of.at[ov].set(-1)
    dirty = s.dirty.at[ov].set(False)
    prefetched = s.prefetched.at[ov].set(False)  # unread prefetch wasted

    # ---- page-in: ONE batched gather over the slab page view ------------
    vin = jnp.where(ok, fetch, V)
    pages = kops.gather_pages(slab[None], jnp.where(ok, fetch, -1),
                              impl=cfg.kernel_impl, masked=False)[0]
    fdst = jnp.where(ok, vic, F)
    frames = s.frames.at[fdst].set(pages)
    backing = backing.at[vin].set(jnp.int8(LOCAL))
    frame_of = frame_of.at[vin].set(vic)
    vpage_of = s.vpage_of.at[fdst].set(jnp.where(ok, fetch, -1))
    cat = cat.at[vin].set(False)
    clock = s.clock.at[vin].set(s.step)
    prefetched = prefetched.at[vin].set(is_pf)
    return s._replace(
        slab=slab, frames=frames, backing=backing, frame_of=frame_of,
        vpage_of=vpage_of, cat=cat, psf=psf, dirty=dirty, clock=clock,
        prefetched=prefetched,
        stats=st.bump(
            s.stats,
            page_ins=jnp.sum(ok.astype(jnp.int32)),
            page_outs=jnp.sum(evict.astype(jnp.int32)),
            dirty_page_outs=n_dirty, psf_to_paging=flip_p,
            psf_to_runtime=flip_r,
            prefetch_issued=jnp.sum((ok & is_pf).astype(jnp.int32))))


def _account_prefetch_hits(cfg: PlaneConfig, s: st.PlaneState,
                           plan: AccessPlan) -> st.PlaneState:
    """Coverage accounting against batch-entry state: a demand access to a
    page whose ``prefetched`` bit is standing means that prefetch turned a
    would-be miss into a hit.  Mode-independent (pure vectorized), so both
    executors agree."""
    used = (jnp.zeros((cfg.num_vpages,), bool).at[plan.vpage].set(True)
            & s.prefetched)
    n_used = jnp.sum(used.astype(jnp.int32))
    return s._replace(prefetched=s.prefetched & ~used,
                      stats=st.bump(s.stats, prefetch_used=n_used))


# --------------------------------------------------------------------------
# execution: runtime plan
# --------------------------------------------------------------------------

def _exec_runtime(cfg: PlaneConfig, s: st.PlaneState, obj_plan: jnp.ndarray,
                  n_move: jnp.ndarray, *, scalar: bool) -> st.PlaneState:
    """Move the deduped miss objects onto the ingress fill page(s).

    The append-slot of every object is computed up front with prefix
    arithmetic over the fill cursor; fresh log pages are allocated before
    any row moves (so allocation can never page out a page that still has
    pending appends).  The batched executor then fetches all rows with one
    ``gather_rows`` call and scatters them into the frame pool; the scalar
    executor replays the same plan one row at a time."""
    P, V, F, O = cfg.page_objs, cfg.num_vpages, cfg.num_frames, cfg.num_objs
    R, D = obj_plan.shape[0], cfg.obj_dim

    # ---- fill-capacity plan (prefix arithmetic over the cursor state)
    cur0 = s.fill_vpage
    have = cur0 >= 0
    a0 = jnp.where(have, s.alloc_count[jnp.maximum(cur0, 0)], P)
    free0 = P - a0                       # free slots on the current cursor
    use0 = jnp.minimum(n_move, free0)
    overflow = n_move - use0
    n_fresh = (overflow + P - 1) // P    # fresh log pages needed
    MAXF = (R + P - 1) // P + 1          # static bound

    def alloc_body(j, carry):
        s, fresh = carry
        s, v = paths._fresh_vpage(cfg, s)        # pinned on allocation
        return s, fresh.at[j].set(v)

    fresh0 = jnp.full((MAXF,), -1, jnp.int32)
    s, fresh = lax.fori_loop(0, n_fresh, alloc_body, (s, fresh0))

    # ---- destination of move t: cursor first, then fresh pages in order
    t = jnp.arange(R, dtype=jnp.int32)
    valid = t < n_move
    tt = t - use0
    in_cur = t < use0
    v_new = jnp.where(in_cur, jnp.maximum(cur0, 0),
                      fresh[jnp.clip(tt // P, 0, MAXF - 1)])
    v_new = jnp.where(valid, v_new, 0)
    slot_new = jnp.where(valid, jnp.where(in_cur, a0 + t, tt % P), 0)

    o = jnp.maximum(obj_plan, 0)
    old = s.obj_loc[o]
    v_old, slot_old = old // P, old % P

    if scalar:
        def move_body(k, s):
            f_new = s.frame_of[v_new[k]]
            row = s.slab[v_old[k], slot_old[k]]
            s = s._replace(
                frames=s.frames.at[f_new, slot_new[k]].set(row),
                obj_loc=s.obj_loc.at[o[k]].set(v_new[k] * P + slot_new[k]),
                obj_of=s.obj_of.at[v_new[k], slot_new[k]].set(o[k]),
                alloc_count=s.alloc_count.at[v_new[k]].add(1),
                live_count=s.live_count.at[v_new[k]].add(1),
                cat=s.cat.at[v_new[k], slot_new[k]].set(True),
            )
            return paths._kill_old_copy(cfg, s, v_old[k], slot_old[k])

        s = lax.fori_loop(0, n_move, move_body, s)
    else:
        # one batched gather (the Pallas object-ingress kernel on TPU) ...
        src_flat = jnp.where(valid, v_old * P + slot_old, -1)
        rows = kops.gather_rows(s.slab.reshape(V * P, D), src_flat,
                                impl=cfg.kernel_impl)
        # ... and one batched scatter into the frame pool
        f_dst = jnp.where(valid, s.frame_of[v_new] * P + slot_new, F * P)
        frames = s.frames.reshape(F * P, D).at[f_dst].set(rows)

        dst_flat = jnp.where(valid, v_new * P + slot_new, V * P)
        old_flat = jnp.where(valid, v_old * P + slot_old, V * P)
        v_new_m = jnp.where(valid, v_new, V)
        v_old_m = jnp.where(valid, v_old, V)
        obj_of = s.obj_of.reshape(V * P).at[dst_flat].set(o)
        obj_of = obj_of.at[old_flat].set(-1)
        live = s.live_count.at[v_new_m].add(1).at[v_old_m].add(-1)
        s = s._replace(
            frames=frames.reshape(F, P, D),
            obj_loc=s.obj_loc.at[jnp.where(valid, o, O)].set(
                v_new * P + slot_new),
            obj_of=obj_of.reshape(V, P),
            alloc_count=s.alloc_count.at[v_new_m].add(1),
            live_count=live,
            cat=s.cat.reshape(V * P).at[dst_flat].set(True).reshape(V, P),
        )
        # GC source pages this batch fully drained (deferred equivalent of
        # the scalar path's per-move _kill_old_copy)
        touched = jnp.zeros((V,), bool).at[v_old_m].set(True)
        drained = touched & (s.live_count == 0) & (s.pin == 0)
        s = s._replace(
            backing=jnp.where(drained, jnp.int8(FREE), s.backing),
            dirty=jnp.where(drained, False, s.dirty),
        )

    # ---- cursor bookkeeping: the last fresh page becomes the fill cursor;
    # the retired cursor and intermediate (already-full) fresh pages unpin
    retired = (n_fresh > 0) & have
    pin = s.pin.at[jnp.where(retired, jnp.maximum(cur0, 0), V)].add(-1)
    j = jnp.arange(MAXF)
    interm = jnp.where(j < n_fresh - 1, jnp.maximum(fresh, 0), V)
    pin = pin.at[interm].add(-1)
    new_cursor = jnp.where(n_fresh > 0,
                           fresh[jnp.clip(n_fresh - 1, 0, MAXF - 1)], cur0)
    return s._replace(pin=pin, fill_vpage=new_cursor,
                      stats=st.bump(s.stats, obj_ins=n_move))


# --------------------------------------------------------------------------
# finish: profiling pass + batched result gather
# --------------------------------------------------------------------------

def _profile(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray, *,
             with_cat: bool, with_obj_last: bool, scalar: bool
             ) -> st.PlaneState:
    """Record every access at its *final* location in one vectorized pass
    (cat_update-style: duplicate touches OR together, no scatter hazards).
    Padded (negative-id) requests profile nothing: their scatter indices
    are out of bounds, so both executors drop them identically."""
    valid = obj_ids >= 0
    va = s.obj_loc[jnp.maximum(obj_ids, 0)]
    v, slot = va // cfg.page_objs, va % cfg.page_objs
    v = jnp.where(valid, v, cfg.num_vpages)
    oid = jnp.where(valid, obj_ids, cfg.num_objs)
    if scalar:
        def body(i, s):
            if with_cat:
                s = paths.touch(cfg, s, v[i], slot[i],
                                obj_id=oid[i] if with_obj_last else None)
            else:
                s = s._replace(clock=s.clock.at[v[i]].set(s.step))
                if with_obj_last:
                    s = s._replace(obj_last=s.obj_last.at[oid[i]].set(s.step))
            return s

        return lax.fori_loop(0, obj_ids.shape[0], body, s)
    if with_cat:
        s = s._replace(cat=s.cat.at[v, slot].set(True),
                       access=s.access.at[v, slot].set(True))
    s = s._replace(clock=s.clock.at[v].set(s.step))
    if with_obj_last:
        s = s._replace(obj_last=s.obj_last.at[oid].set(s.step))
    return s


def _gather_final(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                  *, scalar: bool) -> jnp.ndarray:
    """Read every requested row at its final location with one batched
    gather per tier.  Under extreme pressure a target can be paged out
    again mid-batch; its row is then served from the written-back slab
    copy, so the result is ground truth either way.  Padded (negative-id)
    requests read as zero rows in both executors."""
    P, V, F, D = cfg.page_objs, cfg.num_vpages, cfg.num_frames, cfg.obj_dim
    valid = obj_ids >= 0
    va = s.obj_loc[jnp.maximum(obj_ids, 0)]
    v, slot = va // P, va % P
    local = s.backing[v] == LOCAL
    if scalar:
        R = obj_ids.shape[0]
        out = jnp.zeros((R, D), cfg.dtype)

        def body(i, out):
            row = jnp.where(local[i],
                            s.frames[jnp.maximum(s.frame_of[v[i]], 0), slot[i]],
                            s.slab[v[i], slot[i]])
            return lax.dynamic_update_index_in_dim(out, row, i, axis=0)

        out = lax.fori_loop(0, R, body, out)
        return jnp.where(valid[:, None], out, jnp.zeros_like(out))
    fidx = jnp.where(local, jnp.maximum(s.frame_of[v], 0) * P + slot, -1)
    sidx = jnp.where(local, -1, v * P + slot)
    rows_l = kops.gather_rows(s.frames.reshape(F * P, D), fidx,
                              impl=cfg.kernel_impl)
    rows_r = kops.gather_rows(s.slab.reshape(V * P, D), sidx,
                              impl=cfg.kernel_impl)
    rows = jnp.where(local[:, None], rows_l, rows_r)
    return jnp.where(valid[:, None], rows, jnp.zeros_like(rows))


# --------------------------------------------------------------------------
# the engine entry points
# --------------------------------------------------------------------------

def _resolve(cfg: PlaneConfig, mode) -> bool:
    mode = mode or cfg.access_mode
    if mode not in ("batch", "reference"):
        raise ValueError(f"unknown access mode: {mode!r}")
    return mode == "reference"


def execute_access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                   plan: AccessPlan, *, mode: str | None = None):
    """Execute a precomputed ``AccessPlan``: both ingress paths, profiling,
    final gather.

    Shape contract: ``obj_ids`` is ``[R]`` int32 (negative = padded no-op);
    returns ``(state, rows[R, D])`` with zero rows for padded or unserved
    requests.  Determinism invariant: ``mode="batch"`` and
    ``mode="reference"`` replay the *same* plan and produce bit-identical
    states and rows (tests/test_batch_equivalence.py), with or without an
    active fault schedule — the plan already decided every byte that moves.

    This is the second half of ``access``; the serving engine dispatches
    ``plan_access`` and ``execute_access`` as separate device calls so the
    host can enqueue batch N+1's plan while batch N's execute is still
    running (plan shapes depend only on the batch size — DESIGN.md §3b)."""
    scalar = _resolve(cfg, mode)
    nv = jnp.sum((obj_ids >= 0).astype(jnp.int32))   # padded ids don't count
    s = s._replace(step=s.step + 1)
    s = s._replace(stats=st.bump(s.stats, hits=nv - plan.n_miss,
                                 misses=plan.n_miss,
                                 fetch_failures=plan.n_failed,
                                 egress_failures=plan.n_egress))
    # pre-scope barrier analogue: refresh the recency of every target page
    # so mid-batch eviction prefers non-target pages (soft pin; the hard
    # deref-count pins stay host-side, see sync.py).  Unserved (faulted)
    # requests touched nothing — they profile as if padded.
    pids = jnp.where(plan.served, obj_ids, -1)
    s = s._replace(clock=s.clock.at[
        jnp.where(plan.served, plan.vpage, cfg.num_vpages)].set(s.step))
    s = _account_prefetch_hits(cfg, s, plan)
    s = _exec_paging(cfg, s, plan, scalar=scalar)
    s = _exec_runtime(cfg, s, plan.obj_plan, plan.n_objs, scalar=scalar)
    s = _profile(cfg, s, pids, with_cat=True, with_obj_last=True,
                 scalar=scalar)
    rows = _gather_final(cfg, s, pids, scalar=scalar)
    return s, rows


def access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray, *,
           mode: str | None = None, shard=None, degraded: bool = False):
    """Batched hybrid access: plan, execute both ingress paths, profile,
    gather.  Returns ``(state, rows[R, D])``."""
    return execute_access(
        cfg, s, obj_ids,
        plan_access(cfg, s, obj_ids, shard=shard, degraded=degraded),
        mode=mode)


def update(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
           rows: jnp.ndarray, *, mode: str | None = None, shard=None,
           degraded=False) -> st.PlaneState:
    """Batched write-through-local: fault in, overwrite rows (last write
    wins for duplicate ids), mark dirty.  An unserved (fault-masked)
    request writes nothing — neither tier mutates, so a retry later sees
    the pre-fault value (no partial writes).  ``for_update=True`` extends
    that discipline to egress faults: a request whose row would have to be
    written to the remote slab is masked unserved when that write would
    fault (DESIGN.md §6c).

    The plan is built against pre-step state (``plan_access`` never reads
    ``s.step`` itself, so this matches the access path, where the serving
    engine plans one device call ahead of the step increment — keeps the
    fault-model tick stream identical across access and update)."""
    plan = plan_access(cfg, s, obj_ids, shard=shard, degraded=degraded,
                       for_update=True)
    return execute_update(cfg, s, obj_ids, rows, plan, mode=mode)


def execute_update(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                   rows: jnp.ndarray, plan: AccessPlan, *,
                   mode: str | None = None) -> st.PlaneState:
    """Execute a precomputed write-through plan: the second half of
    ``update``, split out (like ``plan_access``/``execute_access``) so the
    sharded exchange can interleave a round's plan and execute with the
    neighbouring rounds' collectives (repro.core.shardplane)."""
    scalar = _resolve(cfg, mode)
    P, V, F = cfg.page_objs, cfg.num_vpages, cfg.num_frames
    R = obj_ids.shape[0]
    rows = rows.astype(cfg.dtype)
    s = s._replace(step=s.step + 1)
    valid = obj_ids >= 0
    nv = jnp.sum(valid.astype(jnp.int32))
    s = s._replace(stats=st.bump(s.stats, hits=nv - plan.n_miss,
                                 misses=plan.n_miss,
                                 fetch_failures=plan.n_failed,
                                 egress_failures=plan.n_egress))
    served = plan.served
    pids = jnp.where(served, obj_ids, -1)
    s = s._replace(clock=s.clock.at[
        jnp.where(served, plan.vpage, V)].set(s.step))
    s = _account_prefetch_hits(cfg, s, plan)
    s = _exec_paging(cfg, s, plan, scalar=scalar)
    s = _exec_runtime(cfg, s, plan.obj_plan, plan.n_objs, scalar=scalar)
    s = _profile(cfg, s, pids, with_cat=True, with_obj_last=True,
                 scalar=scalar)

    va = s.obj_loc[jnp.maximum(obj_ids, 0)]
    v, slot = va // P, va % P
    local = s.backing[v] == LOCAL
    # padded (negative-id) and unserved (faulted) requests write nothing:
    # sentinel indices drop, so a failed write never mutates either tier
    vw = jnp.where(served, v, V)
    if scalar:
        def body(i, s):
            def to_frames(s):
                f = jnp.maximum(s.frame_of[v[i]], 0)
                return s._replace(
                    frames=s.frames.at[f, slot[i]].set(rows[i]),
                    dirty=s.dirty.at[v[i]].set(True))

            def to_slab(s):
                return s._replace(slab=s.slab.at[vw[i], slot[i]].set(rows[i]))

            return lax.cond(served[i] & local[i], to_frames, to_slab, s)

        return lax.fori_loop(0, R, body, s)

    # last-wins dedup for duplicate ids, then one scatter per tier
    i = jnp.arange(R, dtype=jnp.int32)
    same = (obj_ids[None, :] == obj_ids[:, None])
    last = (jnp.max(jnp.where(same, i[None, :], -1), axis=1) == i) & served
    fidx = jnp.where(last & local, jnp.maximum(s.frame_of[v], 0) * P + slot,
                     F * P)
    sidx = jnp.where(last & ~local, v * P + slot, V * P)
    D = cfg.obj_dim
    return s._replace(
        frames=s.frames.reshape(F * P, D).at[fidx].set(rows).reshape(F, P, D),
        slab=s.slab.reshape(V * P, D).at[sidx].set(rows).reshape(
            cfg.num_vpages, P, D),
        dirty=s.dirty.at[jnp.where(served & local, v, V)].set(True),
    )


# --------------------------------------------------------------------------
# evacuation append-stream planning (used by plane.evacuate)
# --------------------------------------------------------------------------

def plan_append_stream(cfg: PlaneConfig, s: st.PlaneState, which: str,
                       mask: jnp.ndarray):
    """Plan appending the masked slots of one page to the named fill stream.

    ``mask`` is a [P] bool of source slots (so at most one fresh page is
    ever needed).  Allocates that fresh page up front (pinned), updates the
    stream cursor and the destination alloc/live counts, and returns
    ``(state, v_new[P], slot_new[P], in_cur[P], cursor_page, fresh_page,
    retired_page)`` where the destination arrays are only meaningful where
    ``mask`` holds and ``in_cur`` says whether a slot lands on the
    pre-existing cursor page (vs the fresh page).

    A cursor that fills up retires, but it is NOT unpinned here: its
    destination slots have not been written yet, and a later allocation
    (the other evacuation stream's fresh page) could otherwise pick the
    unpinned page as an eviction victim while writes are pending.  The
    caller must unpin ``retired_page`` (when >= 0) after the data
    movement lands."""
    P, V = cfg.page_objs, cfg.num_vpages
    n = jnp.sum(mask.astype(jnp.int32))
    cur0 = getattr(s, which)
    have = cur0 >= 0
    a0 = jnp.where(have, s.alloc_count[jnp.maximum(cur0, 0)], P)
    free0 = P - a0
    use0 = jnp.minimum(n, free0)
    need_fresh = n > free0

    s, vfresh = lax.cond(
        need_fresh,
        lambda s: paths._fresh_vpage(cfg, s),
        lambda s: (s, jnp.asarray(-1, jnp.int32)), s)

    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    in_cur = rank < use0
    v_new = jnp.where(in_cur, jnp.maximum(cur0, 0), jnp.maximum(vfresh, 0))
    slot_new = jnp.where(in_cur, a0 + rank, rank - use0)

    vm = jnp.where(mask, v_new, V)
    s = s._replace(alloc_count=s.alloc_count.at[vm].add(1),
                   live_count=s.live_count.at[vm].add(1))
    # cursor bookkeeping: a filled cursor retires (deferred unpin, see above)
    retired_page = jnp.where(need_fresh & have, cur0, -1)
    new_cur = jnp.where(need_fresh, vfresh, cur0)
    s = s._replace(**{which: new_cur})
    used_cur = jnp.where(use0 > 0, cur0, -1)
    return s, v_new, slot_new, in_cur, used_cur, vfresh, retired_page


# --------------------------------------------------------------------------
# baseline planes on the same engine
# --------------------------------------------------------------------------

def execute_paging_access(cfg: PlaneConfig, s: st.PlaneState,
                          obj_ids: jnp.ndarray, plan: AccessPlan, *,
                          mode: str | None = None):
    """Execute a Fastswap-analogue plan (built with ``split_by_psf=False``:
    every miss takes the paging path; no CAT, no object moves)."""
    scalar = _resolve(cfg, mode)
    nv = jnp.sum((obj_ids >= 0).astype(jnp.int32))
    s = s._replace(step=s.step + 1)
    s = s._replace(stats=st.bump(s.stats, hits=nv - plan.n_miss,
                                 misses=plan.n_miss,
                                 fetch_failures=plan.n_failed,
                                 egress_failures=plan.n_egress))
    pids = jnp.where(plan.served, obj_ids, -1)
    # page-level recency only (no card profiling — that's the point)
    s = s._replace(clock=s.clock.at[
        jnp.where(plan.served, plan.vpage, cfg.num_vpages)].set(s.step))
    s = _account_prefetch_hits(cfg, s, plan)
    s = _exec_paging(cfg, s, plan, scalar=scalar)
    rows = _gather_final(cfg, s, pids, scalar=scalar)
    return s, rows


def paging_access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                  *, mode: str | None = None, shard=None,
                  degraded: bool = False):
    """Fastswap-analogue plane on the batch engine."""
    plan = plan_access(cfg, s, obj_ids, split_by_psf=False, shard=shard,
                       degraded=degraded)
    return execute_paging_access(cfg, s, obj_ids, plan, mode=mode)


def execute_object_access(cfg: PlaneConfig, s: st.PlaneState,
                          obj_ids: jnp.ndarray, plan: AccessPlan,
                          reclaim_free_target: int = 2, *,
                          mode: str | None = None, reclaim=None):
    """Execute an AIFM-analogue plan (built with ``all_runtime=True``:
    every miss object-fetches through the runtime plan); afterwards the
    caller-supplied ``reclaim`` (the object-level LRU egress loop) runs if
    frames are tight."""
    scalar = _resolve(cfg, mode)
    nv = jnp.sum((obj_ids >= 0).astype(jnp.int32))
    s = s._replace(step=s.step + 1)
    s = s._replace(stats=st.bump(s.stats, hits=nv - plan.n_miss,
                                 misses=plan.n_miss,
                                 fetch_failures=plan.n_failed,
                                 egress_failures=plan.n_egress))
    pids = jnp.where(plan.served, obj_ids, -1)
    s = s._replace(clock=s.clock.at[
        jnp.where(plan.served, plan.vpage, cfg.num_vpages)].set(s.step))
    s = _exec_runtime(cfg, s, plan.obj_plan, plan.n_objs, scalar=scalar)
    # object-level hotness tracking (the expensive always-on metadata)
    s = _profile(cfg, s, pids, with_cat=False, with_obj_last=True,
                 scalar=scalar)
    rows = _gather_final(cfg, s, pids, scalar=scalar)
    if reclaim is not None:
        s = reclaim(cfg, s, reclaim_free_target)
    return s, rows


def object_access(cfg: PlaneConfig, s: st.PlaneState, obj_ids: jnp.ndarray,
                  reclaim_free_target: int = 2, *, mode: str | None = None,
                  reclaim=None, shard=None, degraded: bool = False):
    """AIFM-analogue plane on the batch engine."""
    plan = plan_access(cfg, s, obj_ids, all_runtime=True, shard=shard,
                       degraded=degraded)
    return execute_object_access(cfg, s, obj_ids, plan, reclaim_free_target,
                                 mode=mode, reclaim=reclaim)
