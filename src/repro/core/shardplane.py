"""Sharded far tier: the hybrid data plane partitioned over a ``far`` axis.

The single-device plane funnels every request batch through ONE slab and
frame pool, so aggregate ingress bandwidth is capped at a single chip.
This module partitions the vpage space across ``shards`` devices: shard
``s`` owns global objects ``[s*O, (s+1)*O)`` (``O`` per-shard), a
contiguous slab partition, its own frame pool, CAT/CAR/EMA profiling state
and governor threshold — a complete per-shard ``PlaneState``, stacked on a
leading shard axis and laid out with ``mesh.far_specs``.

Access is a fixed-shape, round-based exchange (DESIGN.md §Sharded far
tier):

  1. **Pack** (per source shard): dedup the pending ids in
     first-appearance order, bucket them by owner (``owner = id // O`` —
     static, because fill pages are always allocated from the owner's own
     partition, so objects never migrate across shards), and take the
     first ``per_shard_budget`` per destination.  Overflow **spills** to
     the next round (counted in ``stats.ingress_spills``); a duplicate
     multiplicity rides along so the owner can account the collapsed
     requests as hits exactly like the single plane does.
  2. **all_to_all #1**: the ``[S, B]`` id buffers (and counts) transpose
     source-major -> destination-major across the ``far`` axis.
  3. **Serve** (per owner shard): translate to local ids and run today's
     single-device plan-then-execute engine (``batch.access`` and the
     Pallas kernels) against the shard's own partition — padded slots are
     the engine's negative-id no-ops.
  4. **all_to_all #2**: the demand rows return to their requesters, which
     scatter them into request order.

``rounds = ceil(shard_batch / per_shard_budget)`` is static, so every
request is served within one ``access`` call no matter how skewed the
batch; with the default budget (= ``shard_batch``) there is exactly one
round and nothing ever spills.

**Exchange scheduling** (``ShardedPlaneConfig.exchange``): the legacy
``"serial"`` schedule runs pack -> a2a(ids) -> a2a(counts) -> serve ->
a2a(rows) strictly in sequence, three collectives per round.  The default
``"overlap"`` schedule (DESIGN.md §5d) fuses the side channels into one
packed payload per direction (``kernels.ops.fuse_ids_counts`` /
``fuse_rows_flags`` — two collectives per round) and software-pipelines
the rounds: round r+1's pack + ingress collective is issued before round
r's serve retires, and round r's return-row collective overlaps round
r+1's serve (a ``fori`` steady state with a one-round prologue/epilogue
and a depth-2 return buffer whose all\\ -1 dummy round collects as a
bitwise no-op).  Both schedules compute identical values — the pack chain
depends only on the request ids, so reordering its *issue* against the
serves changes nothing — and every buffer keeps its fixed shape, so the
spill protocol and the jit caches are untouched.

The governor aggregates globally: ``advance_epoch`` all-gathers each
shard's epoch byte deltas and hands every shard the same ``(d_page,
d_obj)`` total, so the adaptive thresholds move in lockstep (a
deterministic psum — fixed summation order keeps it bit-reproducible).

**Bit-equivalence discipline** (continuing ``mode="reference"`` from PRs
1-3): every phase above is a plain per-shard function.  The single-device
oracle runs them under ``vmap`` with the collectives emulated as
transposes of the stacked arrays (``mesh=None``); the multi-device path
runs the identical functions inside ``shard_map`` with ``lax.all_to_all``
/ ``lax.all_gather``.  Both execute the same op sequence per shard, so
rows AND full final state match bit-for-bit (tests/test_sharded.py), and
``shards=1`` with the default budget degenerates to the plain plane —
bitwise, stats included.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from . import baselines
from . import batch as batch_lib
from . import plane as plane_lib
from . import state as st
from .layout import FREE, PlaneConfig


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedPlaneConfig:
    """Static description of a sharded plane (hashable / jit-static).

    ``shard`` is the PER-SHARD plane config (local sizes); the global
    object space is ``shards * shard.num_objs`` ids, owner-major."""

    shard: PlaneConfig
    shards: int                 # S: size of the `far` axis
    shard_batch: int            # R: requests per shard per access call
    per_shard_budget: int       # B: ids exchanged per (src, dst) per round
    plane: str = "hybrid"       # hybrid | paging | object
    exchange: str = "overlap"   # "overlap" pipelined 2-hop | "serial" 3-hop

    def __post_init__(self):
        assert self.shards >= 1
        assert self.shard_batch >= 1
        assert 1 <= self.per_shard_budget <= self.shard_batch
        assert self.plane in ("hybrid", "paging", "object"), self.plane
        assert self.exchange in ("overlap", "serial"), self.exchange

    @property
    def rounds(self) -> int:
        """Static round count: even if every pending id targets one owner,
        ceil(R/B) rounds drain the worst-case per-destination queue."""
        return -(-self.shard_batch // self.per_shard_budget)

    @property
    def num_objs(self) -> int:
        return self.shards * self.shard.num_objs


def shard_config(cfg: PlaneConfig, shards: int) -> PlaneConfig:
    """Slice a GLOBAL plane config into the per-shard config: objects,
    frames and vpages divide evenly across shards (asserted)."""
    for field, n in (("num_objs", cfg.num_objs),
                     ("num_frames", cfg.num_frames),
                     ("num_vpages", cfg.num_vpages)):
        assert n % shards == 0, (
            f"{field}={n} must divide evenly across {shards} shards")
    return dataclasses.replace(cfg, num_objs=cfg.num_objs // shards,
                               num_frames=cfg.num_frames // shards,
                               num_vpages=cfg.num_vpages // shards)


def make_config(cfg: PlaneConfig, shards: int, shard_batch: int,
                per_shard_budget: int | None = None,
                plane: str = "hybrid",
                exchange: str = "overlap") -> ShardedPlaneConfig:
    """Build a sharded config from a GLOBAL plane config.  The default
    budget (= ``shard_batch``) gives one exchange round and no spills."""
    return ShardedPlaneConfig(
        shard=shard_config(cfg, shards), shards=shards,
        shard_batch=shard_batch,
        per_shard_budget=per_shard_budget or shard_batch, plane=plane,
        exchange=exchange)


def create(cfg: ShardedPlaneConfig, initial: jnp.ndarray) -> st.PlaneState:
    """Stacked ``[S, ...]`` plane over the global ``[S*O, D]`` objects."""
    return st.create_sharded(cfg.shard, cfg.shards, initial)


# --------------------------------------------------------------------------
# per-shard phases (shared verbatim by the vmap oracle and shard_map)
# --------------------------------------------------------------------------

def _pack_round(cfg: ShardedPlaneConfig, ids, todo):
    """One shard's send buffers for one round.

    ``ids [R]`` global object ids (< 0 = padding); ``todo [R]`` bool marks
    requests not yet served.  Dedup in first-appearance order, bucket by
    owner, keep the first ``B`` per destination; the rest spill.

    Returns ``(send [S, B] ids (-1 pad), cnt [S, B] duplicate multiplicity,
    todo' [R], n_spill [])``."""
    S, B, R = cfg.shards, cfg.per_shard_budget, cfg.shard_batch
    Os = cfg.shard.num_objs
    first = batch_lib._first_of(ids, todo)
    owner = jnp.where(first, ids // Os, S)
    i = jnp.arange(R, dtype=jnp.int32)
    ahead = ((owner[None, :] == owner[:, None]) & first[None, :]
             & (i[None, :] < i[:, None]))
    rank = jnp.sum(ahead.astype(jnp.int32), axis=1)   # per-destination rank
    sent = first & (rank < B)
    dst = jnp.where(sent, owner, S)                   # OOB scatter = drop
    slot = jnp.where(sent, rank, 0)
    send = jnp.full((S, B), -1, jnp.int32).at[dst, slot].set(ids)
    flat = send.reshape(S * B)
    # duplicate multiplicity: how many pending requests each sent id covers
    # (the owner credits cnt-1 extra hits — single-plane dup-hit semantics)
    cnt = jnp.sum((flat[:, None] == ids[None, :]) & todo[None, :], axis=1)
    cnt = jnp.where(flat >= 0, cnt, 0).astype(jnp.int32).reshape(S, B)
    served = jnp.any((ids[:, None] == flat[None, :]) & (flat[None, :] >= 0),
                     axis=1)
    n_spill = jnp.sum((first & ~sent).astype(jnp.int32))
    return send, cnt, todo & ~served, n_spill


def _serve_round(cfg: ShardedPlaneConfig, s, recv, recv_cnt, me, *, mode,
                 degraded: bool = False):
    """Serve one round's received ids against this shard's own plane.
    ``recv/recv_cnt [S, B]`` destination-major buffers; ``me`` the shard
    index.  Returns ``(state, rows [S, B, D], served [S, B])`` (source-
    major again after the reshape — row block ``j`` answers source shard
    ``j``).  ``me`` keys the fault model's per-shard stream, so a
    scheduled outage of shard k fails exactly the fetches k itself would
    have performed."""
    S, B, D = cfg.shards, cfg.per_shard_budget, cfg.shard.obj_dim
    ok = recv >= 0
    lids = jnp.where(ok, recv - me * cfg.shard.num_objs, -1).reshape(S * B)
    if cfg.plane == "hybrid":
        plan = batch_lib.plan_access(cfg.shard, s, lids, shard=me,
                                     degraded=degraded)
        s, rows = batch_lib.execute_access(cfg.shard, s, lids, plan,
                                           mode=mode)
    elif cfg.plane == "paging":
        plan = batch_lib.plan_access(cfg.shard, s, lids, split_by_psf=False,
                                     shard=me, degraded=degraded)
        s, rows = batch_lib.execute_paging_access(cfg.shard, s, lids, plan,
                                                  mode=mode)
    else:
        plan = batch_lib.plan_access(cfg.shard, s, lids, all_runtime=True,
                                     shard=me, degraded=degraded)
        s, rows = batch_lib.execute_object_access(
            cfg.shard, s, lids, plan, mode=mode,
            reclaim=baselines.object_reclaim)
    extra = jnp.sum(jnp.where(ok, recv_cnt - 1, 0)).astype(jnp.int32)
    s = s._replace(stats=st.bump(s.stats, hits=extra))
    return s, rows.reshape(S, B, D), plan.served.reshape(S, B)


def _collect_round(cfg: ShardedPlaneConfig, out, ids, send, got):
    """Scatter one round's returned rows into request order.  ``send [S,B]``
    the ids this shard sent; ``got [S, B, D]`` their rows (back from the
    owners); requests already served in earlier rounds match nothing and
    keep their value."""
    S, B, D = cfg.shards, cfg.per_shard_budget, cfg.shard.obj_dim
    flat = send.reshape(S * B)
    rows = got.reshape(S * B, D)
    match = (ids[:, None] == flat[None, :]) & (flat[None, :] >= 0)
    j = jnp.argmax(match, axis=1)
    hit = jnp.any(match, axis=1)
    return jnp.where(hit[:, None], rows[j], out)


def _collect_served(cfg: ShardedPlaneConfig, out, ids, send, got):
    """Scatter one round's returned served flags into request order (the
    bool analogue of ``_collect_round``; duplicates of a sent id all take
    the owner's verdict)."""
    S, B = cfg.shards, cfg.per_shard_budget
    flat = send.reshape(S * B)
    sv = got.reshape(S * B)
    match = (ids[:, None] == flat[None, :]) & (flat[None, :] >= 0)
    j = jnp.argmax(match, axis=1)
    hit = jnp.any(match, axis=1)
    return jnp.where(hit, sv[j], out)


def _pack_payload(cfg: ShardedPlaneConfig, ids, rows, send):
    """Update payload for one round's send buffer: the LAST-occurrence row
    of each sent id (the single plane's last-write-wins dedup)."""
    S, B, R = cfg.shards, cfg.per_shard_budget, cfg.shard_batch
    flat = send.reshape(S * B)
    i = jnp.arange(R, dtype=jnp.int32)
    match = (flat[:, None] == ids[None, :]) & (flat[:, None] >= 0)
    j = jnp.max(jnp.where(match, i[None, :], -1), axis=1)
    payload = rows[jnp.clip(j, 0, R - 1)]
    payload = jnp.where((j >= 0)[:, None], payload, 0)
    return payload.reshape(S, B, -1).astype(cfg.shard.dtype)


def _serve_update_round(cfg: ShardedPlaneConfig, s, recv, recv_cnt, payload,
                        me, *, mode):
    """Apply one round's received writes to this shard's own plane (the
    same plan-then-execute split as ``_serve_round``, so the pipelined
    schedule interleaves write rounds exactly like read rounds)."""
    S, B, D = cfg.shards, cfg.per_shard_budget, cfg.shard.obj_dim
    ok = recv >= 0
    lids = jnp.where(ok, recv - me * cfg.shard.num_objs, -1).reshape(S * B)
    plan = batch_lib.plan_access(cfg.shard, s, lids, shard=me,
                                 for_update=True)
    s = batch_lib.execute_update(cfg.shard, s, lids,
                                 payload.reshape(S * B, D), plan, mode=mode)
    extra = jnp.sum(jnp.where(ok, recv_cnt - 1, 0)).astype(jnp.int32)
    return s._replace(stats=st.bump(s.stats, hits=extra))


def _epoch_traffic(cfg: PlaneConfig, s) -> jnp.ndarray:
    """One shard's ``[d_page_bytes, d_obj_bytes]`` since its last epoch."""
    d_page = ((s.stats.page_ins - s.epoch_page_ins).astype(jnp.float32)
              * cfg.page_bytes)
    d_obj = ((s.stats.obj_ins - s.epoch_obj_ins).astype(jnp.float32)
             * cfg.row_bytes)
    return jnp.stack([d_page, d_obj])


def _bump_spills(states, spills):
    return states._replace(stats=st.bump(states.stats,
                                         ingress_spills=spills))


# --------------------------------------------------------------------------
# round schedules (written ONCE; the vmap oracle and the shard_map bodies
# inject their own phase closures + collective, so both exchanges execute
# the identical op sequence on both backends)
# --------------------------------------------------------------------------

def _sched_access(cfg: ShardedPlaneConfig, states, ids, *, pack, serve,
                  collect, collect_sv, a2a, with_served):
    """Run every exchange round of one access call.

    ``pack(ids, todo) -> (send, cnt, todo', n_spill)``;
    ``serve(states, recv, recv_cnt) -> (states, rows, served)``;
    ``collect(out, ids, send, rows) -> out``;
    ``collect_sv(out_sv, ids, send, served) -> out_sv``;
    ``a2a`` is the direction transpose (``lax.all_to_all`` inside
    shard_map, a stacked-axis swap on the oracle).  Leading dims come from
    ``ids`` (``[S, R]`` oracle / ``[R]`` per-shard), so the same code
    serves both callers."""
    S, B = cfg.shards, cfg.per_shard_budget
    R, D = cfg.shard_batch, cfg.shard.obj_dim
    lead = ids.shape[:-1]
    todo = ids >= 0
    out = jnp.zeros(lead + (R, D), cfg.shard.dtype)
    out_sv = jnp.zeros(lead + (R,), bool)
    spills = jnp.zeros(lead, jnp.int32)

    if cfg.exchange == "serial":
        # legacy strictly-ordered schedule: three (four with the served
        # channel) collectives per round, each on its own dependence chain
        for _ in range(cfg.rounds):
            send, cnt, todo, nsp = pack(ids, todo)
            spills = spills + nsp
            states, rows, sv = serve(states, a2a(send), a2a(cnt))
            out = collect(out, ids, send, a2a(rows))
            if with_served:
                out_sv = collect_sv(out_sv, ids, send, a2a(sv))
        return _bump_spills(states, spills), out, out_sv

    # -- overlap: fused payloads + software-pipelined rounds ---------------
    def serve_f(states, ing):
        recv, recv_cnt = kops.split_ids_counts(ing)
        states, rows, sv = serve(states, recv, recv_cnt)
        return states, kops.fuse_rows_flags(rows, sv)

    def collect_f(out, out_sv, send, ret):
        rows, sv = kops.split_rows_flags(ret)
        out = collect(out, ids, send, rows)
        if with_served:
            out_sv = collect_sv(out_sv, ids, send, sv)
        return out, out_sv

    # prologue: round 0's ingress is on the wire before any serve runs
    send, cnt, todo, nsp = pack(ids, todo)
    spills = spills + nsp
    ing = a2a(kops.fuse_ids_counts(send, cnt))
    # depth-2 return buffer; the all -1 dummy send matches no request, so
    # the first (dummy) collect is a bitwise no-op
    prev_send = jnp.full(lead + (S, B), -1, jnp.int32)
    prev_ret = jnp.zeros(lead + (S, B, D + 1), cfg.shard.dtype)

    def body(_, c):
        states, todo, out, out_sv, spills, send, ing, p_send, p_ret = c
        # issue round r+1's pack + ingress collective FIRST: it depends
        # only on the request ids, so it overlaps round r's serve below
        n_send, n_cnt, todo, nsp = pack(ids, todo)
        spills = spills + nsp
        n_ing = a2a(kops.fuse_ids_counts(n_send, n_cnt))
        states, ret = serve_f(states, ing)
        # round r's egress overlaps round r+1's serve (collected next trip)
        ret = a2a(ret)
        out, out_sv = collect_f(out, out_sv, p_send, p_ret)
        return (states, todo, out, out_sv, spills, n_send, n_ing, send, ret)

    carry = (states, todo, out, out_sv, spills, send, ing,
             prev_send, prev_ret)
    if cfg.rounds > 1:
        carry = lax.fori_loop(0, cfg.rounds - 1, body, carry)
    states, todo, out, out_sv, spills, send, ing, prev_send, prev_ret = carry
    # epilogue: serve the last round, then drain both outstanding returns
    states, ret = serve_f(states, ing)
    ret = a2a(ret)
    out, out_sv = collect_f(out, out_sv, prev_send, prev_ret)
    out, out_sv = collect_f(out, out_sv, send, ret)
    return _bump_spills(states, spills), out, out_sv


def _sched_update(cfg: ShardedPlaneConfig, states, ids, rows, *, pack,
                  payload_of, serve, a2a):
    """Write-through rounds: same two schedules as ``_sched_access`` minus
    the egress leg (writes return nothing).  Overlap moves two collectives
    per round — the fused ids+counts payload and the row payload (kept
    separate: int32 ids cannot ride bit-safely in a bf16 row buffer)."""
    lead = ids.shape[:-1]
    todo = ids >= 0
    spills = jnp.zeros(lead, jnp.int32)

    if cfg.exchange == "serial":
        for _ in range(cfg.rounds):
            send, cnt, todo, nsp = pack(ids, todo)
            spills = spills + nsp
            payload = payload_of(ids, rows, send)
            states = serve(states, a2a(send), a2a(cnt), a2a(payload))
        return _bump_spills(states, spills)

    def serve_f(states, ing, pay):
        recv, recv_cnt = kops.split_ids_counts(ing)
        return serve(states, recv, recv_cnt, pay)

    send, cnt, todo, nsp = pack(ids, todo)
    spills = spills + nsp
    ing = a2a(kops.fuse_ids_counts(send, cnt))
    pay = a2a(payload_of(ids, rows, send))

    def body(_, c):
        states, todo, spills, ing, pay = c
        n_send, n_cnt, todo, nsp = pack(ids, todo)
        spills = spills + nsp
        n_ing = a2a(kops.fuse_ids_counts(n_send, n_cnt))
        n_pay = a2a(payload_of(ids, rows, n_send))
        states = serve_f(states, ing, pay)
        return (states, todo, spills, n_ing, n_pay)

    carry = (states, todo, spills, ing, pay)
    if cfg.rounds > 1:
        carry = lax.fori_loop(0, cfg.rounds - 1, body, carry)
    states, todo, spills, ing, pay = carry
    states = serve_f(states, ing, pay)
    return _bump_spills(states, spills)


# --------------------------------------------------------------------------
# single-device oracle: vmap over shards, collectives as transposes
# --------------------------------------------------------------------------

def access(cfg: ShardedPlaneConfig, states, ids, *, mode=None,
           degraded=False, with_served: bool = False):
    """Sharded access on ONE device (the bit-equivalence oracle).

    Shape contract: ``states`` is the stacked ``[S, ...]`` plane; ``ids
    [S, R]`` global object ids per source shard (< 0 = padding).  Returns
    ``(states, rows [S, R, D])`` in request order — plus a ``served
    [S, R]`` bool when ``with_served`` (fault-model verdicts riding the
    exchange back to the requesters; padding is never served).

    ``degraded`` is a static bool (all shards degraded, the legacy global
    breaker) or a traced ``[S]`` bool mask — the per-shard breaker
    (DESIGN.md §6c): a masked shard plans no remote I/O and serves local
    hits only, while unmasked shards run the full fast path
    bit-identically to their all-healthy oracle (shard planes are
    independent; only the masked shard's plan changes).  Determinism
    invariant: the vmap oracle and the shard_map path execute the same
    per-shard op sequence and agree bitwise (DESIGN.md §5)."""
    S = cfg.shards
    me = jnp.arange(S, dtype=jnp.int32)
    if isinstance(degraded, bool):
        serve_v = jax.vmap(partial(_serve_round, cfg, mode=mode,
                                   degraded=degraded))
        serve = lambda st_, recv, cnt: serve_v(st_, recv, cnt, me)
    else:
        deg = jnp.asarray(degraded).astype(bool)
        serve_v = jax.vmap(lambda s_, r, c, m, d: _serve_round(
            cfg, s_, r, c, m, mode=mode, degraded=d))
        serve = lambda st_, recv, cnt: serve_v(st_, recv, cnt, me, deg)
    states, out, out_sv = _sched_access(
        cfg, states, ids,
        pack=jax.vmap(partial(_pack_round, cfg)),
        serve=serve,
        collect=jax.vmap(partial(_collect_round, cfg)),
        collect_sv=jax.vmap(partial(_collect_served, cfg)),
        # the emulated all_to_all: [S(src), S(dst), ...] -> [S(dst), S(src), ...]
        a2a=lambda x: jnp.swapaxes(x, 0, 1), with_served=with_served)
    if with_served:
        return states, out, out_sv
    return states, out


def update(cfg: ShardedPlaneConfig, states, ids, rows, *, mode=None):
    """Sharded write-through on ONE device (oracle).  ``rows [S, R, D]``."""
    if cfg.plane != "hybrid":
        raise ValueError("sharded update is a hybrid-plane operation")
    S = cfg.shards
    me = jnp.arange(S, dtype=jnp.int32)
    serve_v = jax.vmap(partial(_serve_update_round, cfg, mode=mode))
    return _sched_update(
        cfg, states, ids, rows,
        pack=jax.vmap(partial(_pack_round, cfg)),
        payload_of=jax.vmap(partial(_pack_payload, cfg)),
        serve=lambda st_, recv, cnt, pay: serve_v(st_, recv, cnt, pay, me),
        a2a=lambda x: jnp.swapaxes(x, 0, 1))


def advance_epoch(cfg: ShardedPlaneConfig, states):
    """Close one epoch on every shard with the GLOBAL traffic aggregate
    (one device; fixed-order sum == the shard_map all_gather combine)."""
    d = jax.vmap(partial(_epoch_traffic, cfg.shard))(states)   # [S, 2]
    tot = jnp.sum(d, axis=0)
    return jax.vmap(lambda s: plane_lib.advance_epoch(
        cfg.shard, s, traffic=(tot[0], tot[1])))(states)


def evacuate(cfg: ShardedPlaneConfig, states, garbage_threshold=None,
             max_pages: int = 16, *, clear_access: bool = True):
    """Per-shard compaction (no cross-shard traffic: objects re-pack onto
    their owner's own fill pages).  Each shard keys the fault model's
    per-shard egress stream with its own index, matching the shard_map
    path's ``lax.axis_index`` bit-for-bit."""
    S = cfg.shards
    me = jnp.arange(S, dtype=jnp.int32)
    return jax.vmap(lambda s_, m: plane_lib.evacuate(
        cfg.shard, s_, garbage_threshold=garbage_threshold,
        max_pages=max_pages, clear_access=clear_access,
        shard=m))(states, me)


# --------------------------------------------------------------------------
# shard_map bodies: identical phases, lax collectives
# --------------------------------------------------------------------------

def _a2a(x):
    return lax.all_to_all(x, "far", split_axis=0, concat_axis=0)


def _access_body(cfg: ShardedPlaneConfig, mode, degraded, with_served,
                 states, ids):
    s = jax.tree.map(lambda x: x[0], states)
    ids = ids[0]
    me = lax.axis_index("far").astype(jnp.int32)
    s, out, out_sv = _sched_access(
        cfg, s, ids,
        pack=partial(_pack_round, cfg),
        serve=lambda st_, recv, cnt: _serve_round(
            cfg, st_, recv, cnt, me, mode=mode, degraded=degraded),
        collect=partial(_collect_round, cfg),
        collect_sv=partial(_collect_served, cfg),
        a2a=_a2a, with_served=with_served)
    s = jax.tree.map(lambda x: x[None], s)
    if with_served:
        return s, out[None], out_sv[None]
    return s, out[None]


def _access_body_degmask(cfg: ShardedPlaneConfig, mode, with_served,
                         states, ids, deg):
    """The per-shard-breaker access body: like ``_access_body`` but the
    degraded flag arrives as data (``deg [S] bool``, one entry per shard)
    instead of baking a static mode into the program — one compiled
    executable serves any mix of tripped and healthy shards."""
    s = jax.tree.map(lambda x: x[0], states)
    ids = ids[0]
    d = deg[0]
    me = lax.axis_index("far").astype(jnp.int32)
    s, out, out_sv = _sched_access(
        cfg, s, ids,
        pack=partial(_pack_round, cfg),
        serve=lambda st_, recv, cnt: _serve_round(
            cfg, st_, recv, cnt, me, mode=mode, degraded=d),
        collect=partial(_collect_round, cfg),
        collect_sv=partial(_collect_served, cfg),
        a2a=_a2a, with_served=with_served)
    s = jax.tree.map(lambda x: x[None], s)
    if with_served:
        return s, out[None], out_sv[None]
    return s, out[None]


def _update_body(cfg: ShardedPlaneConfig, mode, states, ids, rows):
    s = jax.tree.map(lambda x: x[0], states)
    ids, rows = ids[0], rows[0]
    me = lax.axis_index("far").astype(jnp.int32)
    s = _sched_update(
        cfg, s, ids, rows,
        pack=partial(_pack_round, cfg),
        payload_of=partial(_pack_payload, cfg),
        serve=lambda st_, recv, cnt, pay: _serve_update_round(
            cfg, st_, recv, cnt, pay, me, mode=mode),
        a2a=_a2a)
    return jax.tree.map(lambda x: x[None], s)


def _epoch_body(cfg: ShardedPlaneConfig, states):
    s = jax.tree.map(lambda x: x[0], states)
    d = _epoch_traffic(cfg.shard, s)
    # deterministic psum: all_gather + fixed-order sum, bit-identical to
    # the oracle's jnp.sum over the stacked [S, 2] array
    tot = jnp.sum(lax.all_gather(d, "far"), axis=0)
    s = plane_lib.advance_epoch(cfg.shard, s, traffic=(tot[0], tot[1]))
    return jax.tree.map(lambda x: x[None], s)


def _evac_body(cfg: ShardedPlaneConfig, garbage_threshold, max_pages,
               clear_access, states):
    s = jax.tree.map(lambda x: x[0], states)
    me = lax.axis_index("far").astype(jnp.int32)
    s = plane_lib.evacuate(cfg.shard, s, garbage_threshold=garbage_threshold,
                           max_pages=max_pages, clear_access=clear_access,
                           shard=me)
    return jax.tree.map(lambda x: x[None], s)


def _probe_body(cfg: ShardedPlaneConfig, phase, ids):
    """Truncated exchange for phase attribution: ``"pack"`` runs every
    round's pack; ``"ingress"`` additionally moves the fused ingress
    payload.  Returns a per-shard checksum so nothing dead-code
    eliminates."""
    ids = ids[0]
    todo = ids >= 0
    acc = jnp.zeros((), jnp.int32)
    for _ in range(cfg.rounds):
        send, cnt, todo, nsp = _pack_round(cfg, ids, todo)
        x = kops.fuse_ids_counts(send, cnt)
        if phase == "ingress":
            x = _a2a(x)
        acc = acc + jnp.sum(x) + nsp
    return acc[None]


@functools.lru_cache(maxsize=None)
def jitted_phase_probe(cfg: ShardedPlaneConfig, phase: str, mesh):
    """Benchmark-only probe (benchmarks/fig_shard.py): timing ``"pack"``,
    then ``"ingress"`` (pack + fused collective), then a full access gives
    the subtractive pack / collective / serve wall-share breakdown."""
    assert phase in ("pack", "ingress"), phase
    fn = shard_map(partial(_probe_body, cfg, phase), mesh=mesh,
                   in_specs=(P("far"),), out_specs=P("far"),
                   check_rep=False)
    return jax.jit(fn)


# --------------------------------------------------------------------------
# memoized jit entry points (mesh=None -> the single-device oracle)
# --------------------------------------------------------------------------

def _state_specs(cfg: ShardedPlaneConfig):
    init = jax.ShapeDtypeStruct((cfg.num_objs, cfg.shard.obj_dim),
                                cfg.shard.dtype)
    tmpl = jax.eval_shape(partial(create, cfg), init)
    return jax.tree.map(lambda _: P("far"), tmpl)


@functools.lru_cache(maxsize=None)
def _jitted_access(cfg: ShardedPlaneConfig, mode, mesh, with_served,
                   degraded):
    if mesh is None:
        return jax.jit(partial(access, cfg, mode=mode, degraded=degraded,
                               with_served=with_served))
    sp = _state_specs(cfg)
    # check_rep=False: the plane engine contains fori/while loops, which
    # shard_map's replication checker cannot rule on (the state is
    # genuinely sharded anyway)
    outs = ((sp, P("far"), P("far")) if with_served else (sp, P("far")))
    fn = shard_map(partial(_access_body, cfg, mode, degraded, with_served),
                   mesh=mesh, in_specs=(sp, P("far")), out_specs=outs,
                   check_rep=False)
    return jax.jit(fn)


def jitted_access(cfg: ShardedPlaneConfig, mode=None, mesh=None, *,
                  with_served: bool = False, degraded: bool = False):
    """``(states, ids [S, R]) -> (states, rows [S, R, D])``; ``mesh=None``
    runs the vmap oracle on one device, a ``far`` mesh runs shard_map.
    ``with_served=True`` appends the fault model's per-request ``served
    [S, R]`` verdicts; ``degraded=True`` compiles the hits-only
    circuit-breaker variant."""
    return _jitted_access(cfg, mode or cfg.shard.access_mode, mesh,
                          with_served, degraded)


@functools.lru_cache(maxsize=None)
def _jitted_access_degmask(cfg: ShardedPlaneConfig, mode, mesh, with_served):
    if mesh is None:
        def oracle(states, ids, deg):
            return access(cfg, states, ids, mode=mode, degraded=deg,
                          with_served=with_served)
        return jax.jit(oracle)
    sp = _state_specs(cfg)
    outs = ((sp, P("far"), P("far")) if with_served else (sp, P("far")))
    fn = shard_map(partial(_access_body_degmask, cfg, mode, with_served),
                   mesh=mesh, in_specs=(sp, P("far"), P("far")),
                   out_specs=outs, check_rep=False)
    return jax.jit(fn)


def jitted_access_degmask(cfg: ShardedPlaneConfig, mode=None, mesh=None, *,
                          with_served: bool = True):
    """``(states, ids [S, R], deg [S] bool) -> (states, rows, served?)``:
    the per-shard circuit-breaker entry point (DESIGN.md §6c).  Shards
    with ``deg[k]`` set serve local hits only (no remote I/O planned);
    the rest run the full fast path, bit-identically to the plain
    ``jitted_access`` program — passing an all-False mask reproduces it
    exactly, so the engine compiles ONE program for every breaker state."""
    return _jitted_access_degmask(cfg, mode or cfg.shard.access_mode, mesh,
                                  with_served)


@functools.lru_cache(maxsize=None)
def _jitted_update(cfg: ShardedPlaneConfig, mode, mesh):
    if mesh is None:
        return jax.jit(partial(update, cfg, mode=mode))
    sp = _state_specs(cfg)
    fn = shard_map(partial(_update_body, cfg, mode), mesh=mesh,
                   in_specs=(sp, P("far"), P("far")), out_specs=sp,
                   check_rep=False)
    return jax.jit(fn)


def jitted_update(cfg: ShardedPlaneConfig, mode=None, mesh=None):
    return _jitted_update(cfg, mode or cfg.shard.access_mode, mesh)


@functools.lru_cache(maxsize=None)
def _jitted_advance_epoch(cfg: ShardedPlaneConfig, mesh):
    if mesh is None:
        return jax.jit(partial(advance_epoch, cfg))
    sp = _state_specs(cfg)
    fn = shard_map(partial(_epoch_body, cfg), mesh=mesh, in_specs=(sp,),
                   out_specs=sp, check_rep=False)
    return jax.jit(fn)


def jitted_advance_epoch(cfg: ShardedPlaneConfig, mesh=None):
    return _jitted_advance_epoch(cfg, mesh)


@functools.lru_cache(maxsize=None)
def _jitted_evacuate(cfg: ShardedPlaneConfig, garbage_threshold, max_pages,
                     clear_access, mesh):
    if mesh is None:
        return jax.jit(partial(evacuate, cfg,
                               garbage_threshold=garbage_threshold,
                               max_pages=max_pages,
                               clear_access=clear_access))
    sp = _state_specs(cfg)
    fn = shard_map(partial(_evac_body, cfg, garbage_threshold, max_pages,
                           clear_access), mesh=mesh, in_specs=(sp,),
                   out_specs=sp, check_rep=False)
    return jax.jit(fn)


def jitted_evacuate(cfg: ShardedPlaneConfig, garbage_threshold=None,
                    max_pages: int = 16, clear_access: bool = True,
                    mesh=None):
    return _jitted_evacuate(cfg, garbage_threshold, max_pages, clear_access,
                            mesh)


# --------------------------------------------------------------------------
# introspection
# --------------------------------------------------------------------------

def stats_total(states) -> st.PlaneStats:
    """Global counters: sum each stat over the shard axis."""
    return st.PlaneStats(*[jnp.sum(x, axis=0) for x in states.stats])


def paging_fraction(cfg: ShardedPlaneConfig, states) -> jnp.ndarray:
    """Fraction of allocated pages (across ALL shards) on the paging path."""
    allocated = states.backing != FREE
    pg = jnp.sum((states.psf & allocated).astype(jnp.int32))
    return pg / jnp.maximum(jnp.sum(allocated.astype(jnp.int32)), 1)


def check_invariants(cfg: ShardedPlaneConfig, states) -> dict:
    """Per-shard structural invariants, AND-merged (host-side)."""
    out: dict = {}
    for i in range(cfg.shards):
        for k, v in plane_lib.check_invariants(
                cfg.shard, st.shard_slice(states, i)).items():
            out[k] = out.get(k, True) and v
    return out
