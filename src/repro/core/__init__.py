"""The paper's primary contribution: the Atlas hybrid far-memory data plane.

Public surface:

* :mod:`repro.core.layout`    — PlaneConfig + address layout constants
* :mod:`repro.core.state`     — PlaneState pytree, ``create``
* :mod:`repro.core.batch`     — plan-then-execute batch ingress engine
* :mod:`repro.core.plane`     — hybrid ``access``/``update``/``evacuate``
* :mod:`repro.core.baselines` — Fastswap/AIFM-analogue planes
* :mod:`repro.core.shardplane` — the plane sharded over a ``far`` mesh axis
* :mod:`repro.core.sync`      — deref-count (pin) protocol, live-lock guard
* :mod:`repro.core.offload`   — far-side computation (offload space analogue)
* :mod:`repro.core.faults`    — deterministic fault model (chaos schedule)
* :mod:`repro.core.kvplane`   — production tiered KV cache (serve path)
* :mod:`repro.core.expertplane` — production tiered MoE expert store
"""
from .layout import (FREE, LOCAL, REMOTE, PSF_PAGING, PSF_RUNTIME,
                     PlaneConfig)
from .state import PlaneState, PlaneStats, create
from .plane import (access, update, evacuate, plan_evacuate,
                    execute_evacuate, advance_epoch, writeback_all,
                    evict_all, peek, occupancy, paging_fraction,
                    check_invariants, jitted_access, jitted_update,
                    jitted_evacuate, jitted_plan_evacuate,
                    jitted_execute_evacuate, jitted_advance_epoch,
                    jitted_plan_access, jitted_execute_access)
from .baselines import (paging_access, object_access, object_reclaim,
                        jitted_paging_access, jitted_object_access,
                        jitted_plan_paging, jitted_execute_paging,
                        jitted_plan_object, jitted_execute_object)
from . import batch, faults, shardplane, sync, offload

__all__ = [
    "FREE", "LOCAL", "REMOTE", "PSF_PAGING", "PSF_RUNTIME", "PlaneConfig",
    "PlaneState", "PlaneStats", "create",
    "access", "update", "evacuate", "plan_evacuate", "execute_evacuate",
    "advance_epoch", "writeback_all", "evict_all",
    "peek", "occupancy", "paging_fraction", "check_invariants",
    "paging_access", "object_access", "object_reclaim",
    "jitted_access", "jitted_update", "jitted_evacuate",
    "jitted_plan_evacuate", "jitted_execute_evacuate",
    "jitted_advance_epoch",
    "jitted_plan_access", "jitted_execute_access",
    "jitted_paging_access", "jitted_object_access",
    "jitted_plan_paging", "jitted_execute_paging",
    "jitted_plan_object", "jitted_execute_object",
    "batch", "faults", "shardplane", "sync", "offload",
]
