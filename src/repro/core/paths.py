"""The two ingress paths + the single (paging) egress path.

All functions are pure and jit-compatible: indices are traced scalars,
capacities are static.  Data movement between the far tier (``slab``) and
the local tier (``frames``) is done with dynamic slices — on TPU this is a
contiguous DMA per page (paging path) or a row gather (runtime path); the
Pallas kernels in ``repro.kernels`` implement the batched production
versions of both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import state as st
from .layout import FREE, LOCAL, REMOTE, PlaneConfig

INF32 = jnp.iinfo(jnp.int32).max


# --------------------------------------------------------------------------
# profiling primitives (always-on, paper §4.1)
# --------------------------------------------------------------------------

def car_of(cfg: PlaneConfig, s: st.PlaneState, v) -> jnp.ndarray:
    """Card access rate of vpage ``v``: set CAT bits / allocated cards."""
    set_bits = jnp.sum(s.cat[v].astype(jnp.int32))
    denom = jnp.maximum(s.alloc_count[v], 1)
    return set_bits.astype(jnp.float32) / denom.astype(jnp.float32)


def touch(cfg: PlaneConfig, s: st.PlaneState, v, slot, *, write=False,
          obj_id=None) -> st.PlaneState:
    """Record an access: CAT card bit, per-object access bit, page recency."""
    s = s._replace(
        cat=s.cat.at[v, slot].set(True),
        access=s.access.at[v, slot].set(True),
        clock=s.clock.at[v].set(s.step),
    )
    if write:
        s = s._replace(dirty=s.dirty.at[v].set(True))
    if obj_id is not None:  # object-plane LRU timestamp (baseline bookkeeping)
        s = s._replace(obj_last=s.obj_last.at[obj_id].set(s.step))
    return s


def pin_page(s: st.PlaneState, v) -> st.PlaneState:
    return s._replace(pin=s.pin.at[v].add(1))


def unpin_page(s: st.PlaneState, v) -> st.PlaneState:
    return s._replace(pin=s.pin.at[v].add(-1))


# --------------------------------------------------------------------------
# egress: page-out (the only egress path, paper §4.1 "Egress")
# --------------------------------------------------------------------------

def page_out(cfg: PlaneConfig, s: st.PlaneState, f) -> st.PlaneState:
    """Evict frame ``f``: write back to the slab, update PSF from CAR,
    clear the CAT.  Must only be called on an unpinned, occupied frame.

    The PSF decision blends the epoch governor's decayed CAR EMA with the
    instantaneous window CAR (an epoch boundary clears the CAT, so right
    after one the window alone under-measures) and compares against the
    ADAPTIVE threshold ``s.car_thr`` (== ``cfg.car_threshold`` until the
    governor moves it)."""
    v = s.vpage_of[f]
    car = jnp.maximum(car_of(cfg, s, v), s.car_ema[v])
    new_psf = car >= s.car_thr
    old_psf = s.psf[v]
    flip_to_p = jnp.logical_and(~old_psf, new_psf).astype(jnp.int32)
    flip_to_r = jnp.logical_and(old_psf, ~new_psf).astype(jnp.int32)

    dirty = s.dirty[v]
    # Write back unconditionally (a clean page's copy is already identical);
    # ``dirty_page_outs`` counts the transfers a real system would issue.
    slab = lax.dynamic_update_index_in_dim(s.slab, s.frames[f], v, axis=0)

    s = s._replace(
        slab=slab,
        psf=s.psf.at[v].set(new_psf),
        cat=s.cat.at[v].set(False),
        backing=s.backing.at[v].set(REMOTE),
        frame_of=s.frame_of.at[v].set(-1),
        vpage_of=s.vpage_of.at[f].set(-1),
        dirty=s.dirty.at[v].set(False),
        prefetched=s.prefetched.at[v].set(False),  # unread prefetch wasted
        stats=st.bump(s.stats, page_outs=1,
                      dirty_page_outs=dirty.astype(jnp.int32),
                      psf_to_paging=flip_to_p, psf_to_runtime=flip_to_r),
    )
    return s


def _victim_frame(cfg: PlaneConfig, s: st.PlaneState):
    """Page-level clock/LRU victim among unpinned occupied frames.

    Cost is O(F) — this is the paper's point: page-granular victim selection
    scans frames, not objects (the object-plane baseline scans O objects).
    Returns (frame, valid)."""
    v = s.vpage_of  # [F]
    occupied = v >= 0
    pinned = jnp.where(occupied, s.pin[jnp.maximum(v, 0)] > 0, True)
    score = jnp.where(occupied & ~pinned, s.clock[jnp.maximum(v, 0)], INF32)
    f = jnp.argmin(score)
    return f.astype(jnp.int32), score[f] < INF32


def alloc_frame(cfg: PlaneConfig, s: st.PlaneState):
    """Return (state, frame): a free frame, evicting a victim if needed."""
    free = s.vpage_of < 0
    have_free = jnp.any(free)
    f_free = jnp.argmax(free).astype(jnp.int32)

    def _evict(s):
        f, ok = _victim_frame(cfg, s)
        # Under memory pressure with everything pinned a real Atlas forces a
        # PSF flip + page-out (paper §4.2 live-lock note); callers bound the
        # number of pins per batch so ok is always true here (asserted by the
        # property tests).
        return page_out(cfg, s, f), f

    s, f = lax.cond(have_free, lambda s: (s, f_free), _evict, s)
    return s, f


# --------------------------------------------------------------------------
# ingress path 1: paging (whole-page fetch; vaddrs stable, no pointer updates)
# --------------------------------------------------------------------------

def page_in(cfg: PlaneConfig, s: st.PlaneState, v) -> st.PlaneState:
    """Fetch vpage ``v`` (REMOTE -> LOCAL) through the paging path."""
    s, f = alloc_frame(cfg, s)
    page = lax.dynamic_index_in_dim(s.slab, v, axis=0, keepdims=False)
    frames = lax.dynamic_update_index_in_dim(s.frames, page, f, axis=0)
    s = s._replace(
        frames=frames,
        backing=s.backing.at[v].set(LOCAL),
        frame_of=s.frame_of.at[v].set(f),
        vpage_of=s.vpage_of.at[f].set(v),
        cat=s.cat.at[v].set(False),   # "accessed since ... last swapped in"
        clock=s.clock.at[v].set(s.step),
        stats=st.bump(s.stats, page_ins=1),
    )
    return s


def page_in_at(cfg: PlaneConfig, s: st.PlaneState, v, f) -> st.PlaneState:
    """Fetch vpage ``v`` into the GIVEN (already vacated) frame ``f`` —
    the scalar replay body of a planned paging fetch (the batch planner
    chose the victim; ``page_in`` above chooses its own via alloc_frame)."""
    page = lax.dynamic_index_in_dim(s.slab, v, axis=0, keepdims=False)
    frames = lax.dynamic_update_index_in_dim(s.frames, page, f, axis=0)
    return s._replace(
        frames=frames,
        backing=s.backing.at[v].set(LOCAL),
        frame_of=s.frame_of.at[v].set(f),
        vpage_of=s.vpage_of.at[f].set(v),
        cat=s.cat.at[v].set(False),
        clock=s.clock.at[v].set(s.step),
        stats=st.bump(s.stats, page_ins=1),
    )


# --------------------------------------------------------------------------
# ingress path 2: runtime object fetch (log-structured; rewrites obj_loc)
# --------------------------------------------------------------------------

def _fresh_vpage(cfg: PlaneConfig, s: st.PlaneState):
    """Allocate a FREE vpage backed by a fresh frame; returns (state, vpage).
    The new page is pinned (it is an active allocation target)."""
    v = jnp.argmax(s.backing == FREE).astype(jnp.int32)
    s, f = alloc_frame(cfg, s)
    s = s._replace(
        backing=s.backing.at[v].set(LOCAL),
        frame_of=s.frame_of.at[v].set(f),
        vpage_of=s.vpage_of.at[f].set(v),
        alloc_count=s.alloc_count.at[v].set(0),
        live_count=s.live_count.at[v].set(0),
        cat=s.cat.at[v].set(False),
        access=s.access.at[v].set(False),
        obj_of=s.obj_of.at[v].set(-1),
        dirty=s.dirty.at[v].set(True),   # log pages are born dirty
        clock=s.clock.at[v].set(s.step),
        psf=s.psf.at[v].set(cfg.psf_init_paging),
        car_ema=s.car_ema.at[v].set(0.0),
        prefetched=s.prefetched.at[v].set(False),
    )
    return pin_page(s, v), v


def _ensure_fill(cfg: PlaneConfig, s: st.PlaneState, which: str):
    """Make sure the named fill cursor points at a page with a free slot."""
    cur = getattr(s, which)

    def need_new(s):
        full = s.alloc_count[jnp.maximum(cur, 0)] >= cfg.page_objs
        return jnp.logical_or(cur < 0, full)

    def retire_and_alloc(s):
        # retire: unpin the old fill page (it becomes a normal page)
        s = lax.cond(cur >= 0, lambda s: unpin_page(s, cur), lambda s: s, s)
        s, v = _fresh_vpage(cfg, s)
        return s._replace(**{which: v})

    return lax.cond(need_new(s), retire_and_alloc, lambda s: s, s)


def free_page(cfg: PlaneConfig, s: st.PlaneState, v) -> st.PlaneState:
    """Release vpage ``v`` (and its frame, if local) back to the allocator."""
    def drop_frame(s):
        fo = s.frame_of[v]
        return s._replace(vpage_of=s.vpage_of.at[fo].set(-1),
                          frame_of=s.frame_of.at[v].set(-1))

    s = lax.cond(s.frame_of[v] >= 0, drop_frame, lambda s: s, s)
    return s._replace(backing=s.backing.at[v].set(FREE),
                      dirty=s.dirty.at[v].set(False),
                      prefetched=s.prefetched.at[v].set(False))


def _kill_old_copy(cfg: PlaneConfig, s: st.PlaneState, v_old, slot_old
                   ) -> st.PlaneState:
    """Mark an object's previous slot dead; GC the page if it just emptied."""
    s = s._replace(
        obj_of=s.obj_of.at[v_old, slot_old].set(-1),
        live_count=s.live_count.at[v_old].add(-1),
    )
    dead = jnp.logical_and(s.live_count[v_old] == 0, s.pin[v_old] == 0)
    return lax.cond(dead, lambda s: free_page(cfg, s, v_old), lambda s: s, s)


def _append_obj(cfg: PlaneConfig, s: st.PlaneState, o, row, which: str):
    """Append object ``o`` (data ``row``) to the named fill page; rewrites the
    smart pointer and kills the old copy."""
    s = _ensure_fill(cfg, s, which)
    v_new = getattr(s, which)
    slot_new = s.alloc_count[v_new]
    f_new = s.frame_of[v_new]

    old = s.obj_loc[o]
    v_old, slot_old = old // cfg.page_objs, old % cfg.page_objs

    frames = s.frames.at[f_new, slot_new].set(row)
    s = s._replace(
        frames=frames,
        obj_loc=s.obj_loc.at[o].set(v_new * cfg.page_objs + slot_new),
        obj_of=s.obj_of.at[v_new, slot_new].set(o),
        alloc_count=s.alloc_count.at[v_new].add(1),
        live_count=s.live_count.at[v_new].add(1),
    )
    s = _kill_old_copy(cfg, s, v_old, slot_old)
    return s, v_new, slot_new


def object_in(cfg: PlaneConfig, s: st.PlaneState, o) -> st.PlaneState:
    """Fetch a single object through the runtime path: copy its row from the
    far tier onto the ingress fill page (grouping objects accessed close in
    time onto the same page — the locality-manufacturing step)."""
    old = s.obj_loc[o]
    v_old, slot_old = old // cfg.page_objs, old % cfg.page_objs
    row = s.slab[v_old, slot_old]
    s, v_new, slot_new = _append_obj(cfg, s, o, row, "fill_vpage")
    s = s._replace(stats=st.bump(s.stats, obj_ins=1),
                   cat=s.cat.at[v_new, slot_new].set(True))
    return s
