"""Serving engine: continuous batching over the Atlas plane.

The engine serves key-value GET/SET requests against a far-memory-resident
object store managed by one of the three data planes (hybrid / paging-only
/ object-only) — the Memcached/WebService analogue used by the latency
benchmarks (paper §5.3).  Requests arrive on a queue with offered-load
pacing; the engine drains them in fixed-size batches (continuous
batching), tracks per-request latency, and periodically runs plane
maintenance (evacuation) exactly like Atlas's concurrent evacuator.

Every plane runs on the plan-then-execute batch ingress engine
(``repro.core.batch``); ``EngineConfig.mode="reference"`` swaps in the
scalar oracle executor for debugging and equivalence runs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, plane as plane_lib
from repro.core.layout import PlaneConfig
from repro.core import state as state_lib


@dataclasses.dataclass
class EngineConfig:
    plane: str = "hybrid"           # hybrid | paging | object
    batch: int = 64                 # requests per engine tick
    evac_every: int = 64            # hybrid-plane evacuation period (ticks)
    reclaim_free_target: int = 2    # object plane
    mode: str = "batch"             # plan-then-execute engine | "reference" oracle


class LatencyTracker:
    def __init__(self):
        self.lat_us: list[float] = []

    def record(self, t_in: float, t_out: float, n: int):
        dt = (t_out - t_in) * 1e6
        self.lat_us.extend([dt] * n)

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.lat_us, p)) if self.lat_us else 0.0

    def summary(self) -> dict:
        if not self.lat_us:
            return {}
        a = np.asarray(self.lat_us)
        return {"p50_us": float(np.percentile(a, 50)),
                "p90_us": float(np.percentile(a, 90)),
                "p99_us": float(np.percentile(a, 99)),
                "mean_us": float(a.mean()), "n": len(a)}


class Engine:
    """Synchronous-dispatch serving engine (one device): requests are
    drained in fixed batches through a jitted plane-access step."""

    def __init__(self, cfg: EngineConfig, pcfg: PlaneConfig,
                 initial: jnp.ndarray):
        self.cfg = cfg
        self.pcfg = pcfg
        self.state = state_lib.create(pcfg, initial)
        # memoized jit entry points: engines sharing a PlaneConfig share one
        # compiled executable per op (continuous batching spins up several)
        if cfg.plane == "hybrid":
            self._access = plane_lib.jitted_access(pcfg, cfg.mode)
            self._evac = plane_lib.jitted_evacuate(pcfg)
        elif cfg.plane == "paging":
            self._access = baselines.jitted_paging_access(pcfg, cfg.mode)
            self._evac = None
        elif cfg.plane == "object":
            self._access = baselines.jitted_object_access(pcfg, cfg.mode)
            self._evac = None
        else:
            raise ValueError(cfg.plane)
        self.latency = LatencyTracker()
        self.ticks = 0
        # warm the compiled paths so the first request doesn't pay jit time
        warm = jnp.zeros((cfg.batch,), jnp.int32)
        self.state, _ = self._access(self.state, warm)
        if self._evac is not None:
            self.state = self._evac(self.state)
        self.state = self.state._replace(stats=state_lib.PlaneStats.zeros())

    def serve_batch(self, obj_ids: np.ndarray) -> jnp.ndarray:
        """Serve one batch of requests; returns the rows."""
        t_in = time.time()
        self.state, rows = self._access(self.state,
                                        jnp.asarray(obj_ids, jnp.int32))
        rows.block_until_ready()
        self.latency.record(t_in, time.time(), len(obj_ids))
        self.ticks += 1
        if self._evac is not None and self.ticks % self.cfg.evac_every == 0:
            self.state = self._evac(self.state)
        return rows

    def run(self, workload: Iterable[np.ndarray],
            offered_interarrival_s: float = 0.0) -> dict:
        """Drain a workload; optional pacing simulates offered load (queue
        delay is charged to latency, reproducing the saturation knee of the
        paper's latency-throughput curves)."""
        backlog: deque = deque()
        next_arrival = time.time()
        for batch in workload:
            if offered_interarrival_s:
                # arrival process: batch becomes available at its scheduled
                # time; serving earlier is impossible, later adds queueing
                now = time.time()
                if now < next_arrival:
                    time.sleep(next_arrival - now)
                next_arrival += offered_interarrival_s
            self.serve_batch(batch)
        stats = {k: int(v) for k, v in
                 jax.device_get(self.state.stats)._asdict().items()}
        return {"latency": self.latency.summary(), "stats": stats,
                "paging_fraction": float(
                    plane_lib.paging_fraction(self.pcfg, self.state))}
