"""Serving engine: continuous batching over the Atlas plane.

The engine serves key-value GET/SET requests against a far-memory-resident
object store managed by one of the three data planes (hybrid / paging-only
/ object-only) — the Memcached/WebService analogue used by the latency
benchmarks (paper §5.3).  Requests arrive on a queue with offered-load
pacing; the engine drains them in fixed-size batches (continuous
batching), tracks per-request latency, and periodically runs plane
maintenance (evacuation) exactly like Atlas's concurrent evacuator.

Dispatch is **plan-then-execute, double-buffered** (``dispatch=
"pipelined"``, the default): each batch is submitted as two device calls —
``plan_access`` (vectorized classification/dedup; its output shapes depend
only on the batch size) and ``execute_access`` (the data movement).  The
host never blocks at submit time: it enqueues batch N+1's plan + execute
while batch N is still running on device, and only blocks on the oldest
in-flight result once ``pipeline_depth`` batches are outstanding (or when
a caller explicitly asks for rows).  ``dispatch="sync"`` retires every
batch immediately — the serial engine the pipelined one is benchmarked
against; both produce bit-identical rows and plane state
(tests/test_serving.py).

Latency accounting: a request's latency is charged from its *scheduled
arrival time* (the offered-load pacing clock), not from when the engine
got around to serving it — under saturation the queueing delay is real
latency and is measured as such (the saturation knee of the paper's
latency-throughput curves).

Robust serving (chaos mode): with a :class:`repro.core.faults.Schedule`
on ``EngineConfig.faults`` the plane's remote fetches can fail
deterministically; each plan then carries a per-request ``served`` mask
and the engine closes the loop host-side:

* **retry** — unserved requests re-enter the next tick's batch (bounded
  queue, per-request attempt counts, ``max_retries``);
* **shed** — requests past ``deadline_us`` are dropped at admission and
  counted (``shed_policy="deadline"``), never silently queued;
* **watchdog** — ``_retire_one`` polls with a deadline instead of
  blocking forever, so a wedged device call raises instead of hanging;
* **circuit breaker** — an async health probe (the same ``is_ready()``
  pattern as the epoch watermark) tracks the fetch-failure fraction PER
  SHARD (``[2, shards]`` cumulative counters); a shard whose windowed
  fraction reaches ``breaker_threshold`` trips *alone*
  (``breaker_scope="shard"``, the default — DESIGN.md §6c): its requests
  degrade to paging-local serving (local hits only, no remote fetches, no
  victim writes) while healthy shards stay on the full fast path,
  bit-identically to an all-healthy run.  Every
  ``breaker_probe_every``-th tick dispatches tripped shards normally to
  probe far-tier health, and each shard closes again with hysteresis once
  its own probes come back healthy.  ``breaker_scope="global"`` keeps the
  legacy engine-wide decision (one summed fraction trips every shard at
  once) for comparison.

``run`` then reports **goodput** (requests actually served) separately
from raw throughput (served + shed) — the split the fault-window
benchmarks plot (benchmarks/fig_faults.py).

Every plane runs on the plan-then-execute batch ingress engine
(``repro.core.batch``); ``EngineConfig.mode="reference"`` swaps in the
scalar oracle executor for debugging and equivalence runs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, plane as plane_lib, shardplane
from repro.core.layout import PlaneConfig
from repro.core import state as state_lib


@dataclasses.dataclass
class EngineConfig:
    plane: str = "hybrid"           # hybrid | paging | object
    batch: int = 64                 # requests per engine tick
    evac_every: int = 64            # hybrid-plane evacuation period (ticks)
    reclaim_free_target: int = 2    # object plane
    mode: str = "batch"             # plan-then-execute engine | "reference" oracle
    dispatch: str = "pipelined"     # "pipelined" double-buffer | "sync"
    pipeline_depth: int = 2         # max in-flight batches before blocking
    # Background evacuation: 0 = one foreground max_pages=16 compaction
    # every evac_every ticks (the pre-slice behavior); >0 = roughly the
    # foreground round's 16-page budget sliced into evac_budget-page
    # plan+execute calls spread evenly across the round's dispatch gaps
    # (ceil(16/budget) slices per round), so no single batch carries a
    # multi-page compaction on its critical path.  Access bits clear once
    # per round, on its first slice — the sliced round's "end of each
    # evacuation".
    evac_budget: int = 0
    # Epoch governor: advance_epoch every this many ticks (hybrid plane;
    # 0 = off).  Dispatched async like everything else.
    epoch_every: int = 0
    # Load-aware epoch scheduling: close an epoch once the plane has moved
    # this many bytes (paging + object traffic) since the last one (0 =
    # off).  A wall-clock tick schedule under-profiles churn bursts and
    # over-profiles idle stretches; the watermark keys the governor to the
    # traffic that actually moves its thresholds.  ``epoch_every`` stays on
    # as the idle-time fallback.  The probe is an async device read polled
    # with ``is_ready()`` so pipelined dispatch never blocks on it.
    epoch_watermark_bytes: int = 0
    # Sharded far tier: partition the plane over this many devices (1 =
    # the single-device plane).  ``batch`` splits evenly across shards
    # (each shard sources batch/shards requests per tick) and access runs
    # the round-based exchange of repro.core.shardplane — on a ``far``
    # mesh when the Engine gets one, else on the vmap oracle.
    shards: int = 1
    # Per-(src, dst) id budget per exchange round (0 = auto: one round,
    # budget = batch/shards, nothing ever spills).
    shard_budget: int = 0
    # Exchange schedule: "overlap" (default) fuses the side channels into
    # one collective per direction and software-pipelines the rounds so
    # collectives overlap the local serves; "serial" is the legacy
    # strictly-ordered 3-hop schedule (bit-identical results — the
    # equivalence suite runs both).
    shard_exchange: str = "overlap"
    # ---- robust / chaos serving ------------------------------------------
    # Deterministic fault schedule (repro.core.faults.Schedule) injected
    # into the plane config: remote fetches fail per the schedule, plans
    # carry a per-request ``served`` mask, and the engine runs the robust
    # submit/retire path below.  None = fault-free (and, with the other
    # knobs at their defaults, the engine is bit-identical to the plain
    # one — enforced by tests/test_faults.py).
    faults: object = None
    # Per-request latency SLO in microseconds (0 = no deadline).  Measured
    # from the scheduled-arrival clock, same as the latency tracker.
    deadline_us: float = 0.0
    # Re-dispatch attempts for requests whose fetch faulted (0 = a faulted
    # request is shed immediately).  Retries ride in the unused tail slots
    # of later ticks' fixed-size batches, so they never grow the compiled
    # shapes.
    max_retries: int = 0
    # "deadline": drop over-deadline requests at admission (counted in
    # shed_requests + deadline_misses).  "none": admit regardless; late
    # service still counts a deadline_miss at retirement.
    shed_policy: str = "deadline"
    # Bounded retry queue: overflow is shed (counted), never buffered
    # unboundedly — a dead far tier must not OOM the host.
    retry_queue_cap: int = 1024
    # _retire_one watchdog: raise TimeoutError if an in-flight batch is
    # still not ready after this many seconds (0 = block forever, the
    # legacy behavior).
    watchdog_s: float = 120.0
    # Circuit breaker: open (degraded paging-local serving) once an async
    # stats probe sees the windowed fetch-failure fraction reach this
    # value (0 = breaker off).  While open, every breaker_probe_every-th
    # tick dispatches normally to probe far-tier health; the breaker
    # closes again once a probe window's failure fraction falls to
    # threshold * hysteresis (recovery needs to look *better* than the
    # trip point — no flapping on the edge).
    breaker_threshold: float = 0.0
    breaker_probe_every: int = 4
    breaker_hysteresis: float = 0.5
    # "shard" (default): each shard trips and recovers on its OWN windowed
    # failure fraction — a single sick shard degrades alone while healthy
    # shards keep the fast path (their ids masked per shard at plan time
    # via the traced degraded mask, DESIGN.md §6c).  "global": the legacy
    # engine-wide decision on the summed fraction (all shards degrade
    # together).  With shards=1 the two are identical.
    breaker_scope: str = "shard"


class LatencyTracker:
    """Latency sink with **bounded memory**.

    The previous tracker appended every sample to a Python list — a
    day-long soak at 1M req/s is ~0.7 GB of floats.  This one keeps an
    exact streaming count and mean plus a fixed-capacity uniform
    reservoir (Vitter's algorithm R, vectorized, deterministically
    seeded) for the percentiles: up to ``capacity`` samples the
    percentiles are exact; beyond that they are an unbiased estimate
    over a uniform sample of the whole stream.
    """

    def __init__(self, capacity: int = 65536, seed: int = 0x5EED):
        self.capacity = int(capacity)
        self._buf = np.empty((self.capacity,), np.float64)
        self._rng = np.random.RandomState(seed)
        self.n = 0
        self._sum = 0.0

    def record(self, t_in: float, t_out: float, n: int):
        if n > 0:
            self.record_us(np.full((int(n),), (t_out - t_in) * 1e6))

    def record_us(self, lat_us):
        """Record a vector of per-request latencies (microseconds)."""
        lat = np.asarray(lat_us, np.float64).reshape(-1)
        if lat.size == 0:
            return
        self._sum += float(lat.sum())
        pos = self.n + np.arange(lat.size)
        head = pos < self.capacity
        if head.any():
            self._buf[pos[head]] = lat[head]
        tail = ~head
        if tail.any():
            # stream element j replaces a random slot with p = capacity/(j+1)
            j = pos[tail]
            r = np.floor(self._rng.random_sample(j.size) * (j + 1)
                         ).astype(np.int64)
            hit = r < self.capacity
            self._buf[r[hit]] = lat[tail][hit]
        self.n += int(lat.size)

    @property
    def lat_us(self) -> list:
        """Retained samples (bounded compat view of the old raw list)."""
        return self._buf[:min(self.n, self.capacity)].tolist()

    def percentile(self, p: float) -> float:
        k = min(self.n, self.capacity)
        return float(np.percentile(self._buf[:k], p)) if k else 0.0

    def summary(self) -> dict:
        if self.n == 0:
            return {}
        a = self._buf[:min(self.n, self.capacity)]
        return {"p50_us": float(np.percentile(a, 50)),
                "p90_us": float(np.percentile(a, 90)),
                "p99_us": float(np.percentile(a, 99)),
                "mean_us": self._sum / self.n, "n": self.n}


class _Inflight(NamedTuple):
    """One dispatched batch awaiting retirement."""
    rows: object            # async device array [batch, D]
    t_sched: float          # batch scheduled-arrival clock (legacy path)
    n: int                  # caller's request count (first n slots)
    served: object = None   # async [batch] bool (robust engines only)
    ids: object = None      # np [batch] int32 slot ids (incl. retries, -1 pad)
    t0s: object = None      # np [batch] float64 per-slot arrival clocks
    att: object = None      # np [batch] int32 per-slot attempt counts


_EMPTY_IDS = np.empty((0,), np.int32)


class Engine:
    """Continuous-batching serving engine (one device).

    ``submit`` enqueues one batch (plan + execute device calls) and returns
    the result as an async array; ``drain`` blocks on everything still in
    flight.  ``serve_batch`` is the synchronous convenience wrapper
    (submit + drain + return rows)."""

    def __init__(self, cfg: EngineConfig, pcfg: PlaneConfig,
                 initial: jnp.ndarray, mesh=None):
        self.cfg = cfg
        if cfg.faults is not None:
            # the schedule rides in the (hashable, static) plane config so
            # every jitted entry point sees the same deterministic streams
            pcfg = dataclasses.replace(pcfg, faults=cfg.faults)
        self.pcfg = pcfg
        self.scfg = None
        sharded = cfg.shards > 1
        epoch_on = (cfg.plane == "hybrid"
                    and (cfg.epoch_every > 0 or cfg.epoch_watermark_bytes > 0))
        self._robust = (cfg.faults is not None or cfg.deadline_us > 0
                        or cfg.max_retries > 0 or cfg.breaker_threshold > 0)
        breaker_on = self._robust and cfg.breaker_threshold > 0
        # memoized jit entry points: engines sharing a PlaneConfig share one
        # compiled executable per op (continuous batching spins up several)
        self._plan = self._exec = self._access = None
        self._evac = self._epoch = self._traffic = None
        self._evac_slice = self._evac_slice_clear = None
        self._plan_deg = self._access_degmask = self._health = None
        if sharded:
            assert cfg.batch % cfg.shards == 0, (
                f"batch={cfg.batch} must split evenly over "
                f"{cfg.shards} shards")
            self.scfg = scfg = shardplane.make_config(
                pcfg, cfg.shards, cfg.batch // cfg.shards,
                cfg.shard_budget or None, plane=cfg.plane,
                exchange=cfg.shard_exchange)
            self.state = shardplane.create(scfg, initial)
            if mesh is not None:
                from repro.launch import mesh as mesh_lib
                self.state = mesh_lib.put_far(self.state, mesh)
            # fused access: the exchange already interleaves plan+execute
            # per round, so there is no host-visible plan/execute split.
            # Robust engines take the served-channel variant (the verdicts
            # ride the exchange back with the rows).
            self._access = shardplane.jitted_access(
                scfg, cfg.mode, mesh, with_served=self._robust)
            if breaker_on:
                # ONE compiled program for every breaker state: the [S]
                # degraded mask arrives as data, so any mix of tripped and
                # healthy shards dispatches without recompiling (all-False
                # reproduces the plain program bit-identically)
                self._access_degmask = shardplane.jitted_access_degmask(
                    scfg, cfg.mode, mesh, with_served=True)
            if cfg.plane == "hybrid":
                self._evac = shardplane.jitted_evacuate(scfg, mesh=mesh)
                if cfg.evac_budget > 0:
                    self._evac_slice = shardplane.jitted_evacuate(
                        scfg, max_pages=cfg.evac_budget, clear_access=False,
                        mesh=mesh)
                    self._evac_slice_clear = shardplane.jitted_evacuate(
                        scfg, max_pages=cfg.evac_budget, clear_access=True,
                        mesh=mesh)
                if epoch_on:
                    self._epoch = shardplane.jitted_advance_epoch(scfg, mesh)
            tcfg = scfg.shard
        elif cfg.plane == "hybrid":
            self.state = state_lib.create(pcfg, initial)
            self._plan = plane_lib.jitted_plan_access(pcfg)
            self._exec = plane_lib.jitted_execute_access(pcfg, cfg.mode)
            if breaker_on:
                self._plan_deg = plane_lib.jitted_plan_access(
                    pcfg, degraded=True)
            self._evac = plane_lib.jitted_evacuate(pcfg)
            if cfg.evac_budget > 0:
                # background slices: each is plan_evacuate+execute_evacuate
                # composed into ONE async device call (a two-call split
                # only pays extra dispatch overhead when plan and execute
                # land in the same gap anyway); same 16-page budget per
                # evac_every round as the foreground call
                self._evac_slice = plane_lib.jitted_evacuate(
                    pcfg, max_pages=cfg.evac_budget, clear_access=False)
                self._evac_slice_clear = plane_lib.jitted_evacuate(
                    pcfg, max_pages=cfg.evac_budget, clear_access=True)
            if epoch_on:
                self._epoch = plane_lib.jitted_advance_epoch(pcfg)
            tcfg = pcfg
        elif cfg.plane == "paging":
            self.state = state_lib.create(pcfg, initial)
            self._plan = baselines.jitted_plan_paging(pcfg)
            self._exec = baselines.jitted_execute_paging(pcfg, cfg.mode)
            if breaker_on:
                self._plan_deg = baselines.jitted_plan_paging(
                    pcfg, degraded=True)
            tcfg = pcfg
        elif cfg.plane == "object":
            self.state = state_lib.create(pcfg, initial)
            self._plan = baselines.jitted_plan_object(pcfg)
            self._exec = baselines.jitted_execute_object(pcfg, cfg.mode)
            if breaker_on:
                self._plan_deg = baselines.jitted_plan_object(
                    pcfg, degraded=True)
            tcfg = pcfg
        else:
            raise ValueError(cfg.plane)
        if self._evac_slice is not None:
            slices = -(-16 // cfg.evac_budget)          # ceil(16/budget)
            self._evac_slice_period = max(1, cfg.evac_every // slices)
            self._evac_round = 0        # last round whose access-clear ran
        if self._epoch is not None and cfg.epoch_watermark_bytes > 0:
            # bytes moved (paging + object ingress) since the last epoch —
            # the same deltas advance_epoch profiles; sharded states sum
            # elementwise over the stacked [S] counters
            pb, rb = float(tcfg.page_bytes), float(tcfg.row_bytes)
            self._traffic = jax.jit(lambda s: jnp.sum(
                (s.stats.page_ins - s.epoch_page_ins).astype(jnp.float32)
                * pb
                + (s.stats.obj_ins - s.epoch_obj_ins).astype(jnp.float32)
                * rb))
        if breaker_on:
            # health probe: cumulative (failed, attempted) remote fetches,
            # kept PER SHARD ([2, shards]; the unsharded plane is one
            # "shard").  Attempts = successful ingress + failures, so
            # degraded ticks (which fetch nothing) contribute ~nothing to
            # either side and a window's fraction measures exactly its
            # *probe* tick's health — a shard's breaker can close off one
            # good probe.  The per-shard columns drive the per-shard trip
            # decision (``breaker_scope="shard"``); ``"global"`` sums them
            # back into the legacy engine-wide signal.
            self._health = jax.jit(lambda s: jnp.stack([
                jnp.atleast_1d(s.stats.fetch_failures
                               ).astype(jnp.float32),
                jnp.atleast_1d(s.stats.page_ins + s.stats.obj_ins
                               + s.stats.fetch_failures
                               ).astype(jnp.float32)]))
        self._probe = None              # in-flight traffic watermark read
        self._hprobe = None             # in-flight health probe read
        self._hlast = np.zeros((2, cfg.shards), np.float64)
        self.shard_fail_frac = np.zeros((cfg.shards,), np.float64)
        self.breaker_open_shards = np.zeros((cfg.shards,), bool)
        self.served_per_shard = np.zeros((cfg.shards,), np.int64)
        self._retryq: deque = deque()   # (obj_id, t0, attempt)
        self.counters = {"served": 0, "fetch_retries": 0, "shed_requests": 0,
                         "deadline_misses": 0, "degraded_ticks": 0,
                         "breaker_trips": 0}
        self.latency = LatencyTracker()
        self.ticks = 0
        self._inflight: deque[_Inflight] = deque()      # oldest-first
        # warm the compiled paths so the first request doesn't pay jit time
        if sharded:
            warm = jnp.zeros((cfg.shards, cfg.batch // cfg.shards),
                             jnp.int32)
            if self._robust:
                self.state, _, _ = self._access(self.state, warm)
            else:
                self.state, _ = self._access(self.state, warm)
        else:
            warm = jnp.zeros((cfg.batch,), jnp.int32)
            self.state, _ = self._exec(self.state, warm,
                                       self._plan(self.state, warm))
        if self._evac is not None:
            self.state = self._evac(self.state)
        if self._evac_slice is not None:
            # compile-cache the background-slice pair (results discarded)
            jax.block_until_ready(self._evac_slice(self.state))
            jax.block_until_ready(self._evac_slice_clear(self.state))
        if self._epoch is not None:
            jax.block_until_ready(self._epoch(self.state))
        if self._traffic is not None:
            jax.block_until_ready(self._traffic(self.state))
        # warm the degraded/probe entries too — compiling them lazily would
        # land the jit cost inside the fault window and pollute its p99.
        # Results are discarded: warmup state stays identical to a plain
        # engine's (the fault-free equivalence tests depend on it).
        if self._plan_deg is not None:
            jax.block_until_ready(self._plan_deg(self.state, warm))
        if self._access_degmask is not None:
            jax.block_until_ready(self._access_degmask(
                self.state, warm, jnp.zeros((cfg.shards,), bool)))
        if self._health is not None:
            jax.block_until_ready(self._health(self.state))
        self.state = self.state._replace(
            stats=jax.tree.map(jnp.zeros_like, self.state.stats),
            epoch_page_ins=jnp.zeros_like(self.state.epoch_page_ins),
            epoch_obj_ins=jnp.zeros_like(self.state.epoch_obj_ins))

    @property
    def breaker_open(self) -> bool:
        """True if ANY shard's breaker is open (back-compat view of the
        per-shard ``breaker_open_shards`` array; with shards=1 it is
        exactly the old engine-global flag)."""
        return bool(self.breaker_open_shards.any())

    # -- pipelined dispatch -------------------------------------------------

    def submit(self, obj_ids: np.ndarray, t_sched: float | None = None):
        """Enqueue one batch; returns its rows as an async device array.

        ``t_sched``: the batch's scheduled arrival time (latency is charged
        from here; defaults to now).  Blocks only when more than
        ``pipeline_depth`` batches are in flight (back-pressure), never on
        the batch being submitted."""
        t_sched = time.time() if t_sched is None else t_sched
        # opportunistic retirement: anything already finished on device is
        # recorded now, so recorded latency tracks actual completion rather
        # than when back-pressure forces a block
        while self._inflight and self._inflight[0].rows.is_ready():
            self._retire_one()
        if self._robust:
            rows = self._submit_robust(obj_ids, t_sched)
        else:
            rows = self._dispatch(obj_ids, t_sched)
        self.ticks += 1
        self._maintenance()
        limit = 0 if self.cfg.dispatch == "sync" else self.cfg.pipeline_depth
        while len(self._inflight) > limit:
            self._retire_one()
        return rows

    def _dispatch(self, obj_ids, t_sched):
        """Fault-free dispatch (the original engine path)."""
        cfg = self.cfg
        ids = jnp.asarray(obj_ids, jnp.int32)
        n = len(obj_ids)
        # short batches pad with the plane's negative-id no-ops: fixed
        # shapes keep one compiled program per engine (sharded and
        # unsharded alike)
        if n < cfg.batch:
            ids = jnp.concatenate(
                [ids, jnp.full((cfg.batch - n,), -1, jnp.int32)])
        if self._access is not None:
            # sharded far tier: the batch splits evenly across source shards
            S, R = cfg.shards, cfg.batch // cfg.shards
            self.state, out = self._access(self.state, ids.reshape(S, R))
            rows_full = out.reshape(cfg.batch, -1)
        else:
            # two async device calls: the plan dispatch is what a sharded
            # deployment runs host-side / on a prefetch stream
            plan = self._plan(self.state, ids)
            self.state, rows_full = self._exec(self.state, ids, plan)
        self._inflight.append(_Inflight(rows_full, t_sched, n))
        return rows_full[:n] if n < cfg.batch else rows_full

    def _submit_robust(self, obj_ids, t_sched):
        """Chaos-mode dispatch: deadline shed at admission, retry slots in
        the batch tail, per-slot served verdicts, circuit-breaker routing."""
        cfg = self.cfg
        ids_np = np.asarray(obj_ids, np.int32).reshape(-1)
        n = ids_np.size
        assert n <= cfg.batch, f"batch of {n} > configured batch={cfg.batch}"
        now = time.time()
        shed = (cfg.deadline_us > 0 and cfg.shed_policy == "deadline"
                and n > 0 and (now - t_sched) * 1e6 > cfg.deadline_us)
        if shed:
            # the whole arrival is already past its SLO: count it out
            # instead of queueing work nobody is waiting for
            self.counters["shed_requests"] += n
            self.counters["deadline_misses"] += n
        full = np.full((cfg.batch,), -1, np.int32)
        t0s = np.full((cfg.batch,), now, np.float64)
        att = np.zeros((cfg.batch,), np.int32)
        k = 0
        if n and not shed:
            # new requests first: returned rows[:n] stay aligned with the
            # caller's ids
            full[:n] = ids_np
            t0s[:n] = t_sched
            k = n
        while self._retryq and k < cfg.batch:
            rid, rt0, ratt = self._retryq.popleft()
            if (cfg.deadline_us > 0 and cfg.shed_policy == "deadline"
                    and (now - rt0) * 1e6 > cfg.deadline_us):
                self.counters["shed_requests"] += 1
                self.counters["deadline_misses"] += 1
                continue
            full[k] = rid
            t0s[k] = rt0
            att[k] = ratt
            k += 1
        tick = self.ticks + 1
        sched = cfg.faults
        if sched is not None:
            # host-visible latency spike: the dispatch path stalls (a
            # remote NIC hiccup), deterministically per the schedule
            d_us = sched.spike(tick)
            if d_us > 0.0:
                time.sleep(d_us * 1e-6)
            # slow-but-alive shard windows: the exchange is collective, so
            # the slowest participating shard gates the whole tick.  Pure
            # latency — it never feeds the failure counters, so a slow
            # shard must NOT trip the breaker (slow != dead, §6c).
            slow = sched.slow_us(tick)
            if slow > 0.0:
                time.sleep(slow * 1e-6)
        # per-shard degraded mask for this tick: tripped shards serve
        # paging-local except on probe ticks, healthy shards always run
        # the fast path (with shards=1 this is the old global flag)
        dmask = np.zeros((cfg.shards,), bool)
        if (self._health is not None and self.breaker_open
                and tick % cfg.breaker_probe_every != 0):
            dmask = self.breaker_open_shards.copy()
            self.counters["degraded_ticks"] += int(dmask.sum())
        ids = jnp.asarray(full)
        if self._access is not None:
            S, R = cfg.shards, cfg.batch // cfg.shards
            if self._access_degmask is not None:
                self.state, out, sv = self._access_degmask(
                    self.state, ids.reshape(S, R), jnp.asarray(dmask))
            else:
                self.state, out, sv = self._access(self.state,
                                                   ids.reshape(S, R))
            rows_full = out.reshape(cfg.batch, -1)
            served = sv.reshape(cfg.batch)
        else:
            plan = (self._plan_deg if dmask[0] else self._plan)(
                self.state, ids)
            self.state, rows_full = self._exec(self.state, ids, plan)
            served = plan.served
        self._inflight.append(_Inflight(rows_full, t_sched, n,
                                        served, full, t0s, att))
        if self._health is not None:
            self._breaker_step()
        if shed:
            return jnp.zeros((n, rows_full.shape[1]), rows_full.dtype)
        return rows_full[:n] if n < cfg.batch else rows_full

    def _maintenance(self):
        """Per-tick background work (evacuation slices, epoch governor)."""
        if self._evac is not None:
            if self.cfg.evac_budget > 0:
                # background evacuation: the foreground round's 16-page
                # budget rides in as evac_budget-page slices spread evenly
                # across the round's dispatch gaps (async device calls —
                # the host moves on to batch N+1 immediately); the
                # access-bit round closes on the evac_every boundary,
                # where the foreground mode used to pay the whole
                # compaction at once
                if self.ticks % self._evac_slice_period == 0:
                    # access bits clear once per evac_every round: on the
                    # first slice of each new round (period need not
                    # divide evac_every)
                    round_id = self.ticks // self.cfg.evac_every
                    if round_id > self._evac_round:
                        self._evac_round = round_id
                        self.state = self._evac_slice_clear(self.state)
                    else:
                        self.state = self._evac_slice(self.state)
            elif self.ticks % self.cfg.evac_every == 0:
                self.state = self._evac(self.state)
        if self._epoch is not None and self._epoch_due():
            self.state = self._epoch(self.state)
            self._probe = None          # watermark restarts from the epoch

    def _epoch_due(self) -> bool:
        """Load-aware epoch schedule: the tick period (``epoch_every``) is
        the fallback; the byte watermark fires as soon as an async traffic
        probe reads past ``epoch_watermark_bytes`` — churn bursts advance
        epochs faster than the wall-clock schedule, idle stretches don't
        churn the governor.  Pipelined dispatch never blocks here: the
        probe is polled with ``is_ready()`` and acted on a tick late."""
        cfg = self.cfg
        if cfg.epoch_every > 0 and self.ticks % cfg.epoch_every == 0:
            return True
        if self._traffic is None:
            return False
        if self._probe is None:
            self._probe = self._traffic(self.state)
            if cfg.dispatch != "sync":
                return False            # poll on a later tick
        if cfg.dispatch == "sync" or self._probe.is_ready():
            due = float(self._probe) >= cfg.epoch_watermark_bytes
            self._probe = None
            return due
        return False

    def _breaker_step(self):
        """Async circuit-breaker update — same non-blocking shape as
        ``_epoch_due``: start a cumulative (failures, attempts) probe,
        poll it with ``is_ready()`` on later ticks, and act on the delta
        since the previous reading.

        ``breaker_scope="shard"`` (default): each shard column trips and
        closes on its OWN windowed failure fraction — a shard only acts
        when its window holds evidence (attempts > 0), opens at
        ``breaker_threshold`` and closes once a window reads back at
        threshold * hysteresis (while open, only probe ticks attempt
        fetches, so the window's fraction is exactly that shard's probes'
        health).  ``"global"``: the legacy decision on the summed
        fractions, all shards together.  ``breaker_trips`` counts
        per-shard openings (engine-wide trips with shards=1)."""
        cfg = self.cfg
        if self._hprobe is None:
            self._hprobe = self._health(self.state)
            if cfg.dispatch != "sync":
                return                  # poll on a later tick
        if cfg.dispatch != "sync" and not self._hprobe.is_ready():
            return
        cur = np.asarray(jax.device_get(self._hprobe),
                         np.float64).reshape(2, -1)
        self._hprobe = None
        d = cur - self._hlast
        self._hlast = cur
        # per-shard window fractions: a single-shard outage lights up one
        # column while the global fraction stays diluted by healthy shards
        self.shard_fail_frac = d[0] / np.maximum(d[1], 1.0)
        thr, hys = cfg.breaker_threshold, cfg.breaker_hysteresis
        if cfg.breaker_scope == "global":
            d_fail, d_att = float(d[0].sum()), float(d[1].sum())
            if d_att <= 0:
                return                  # no fetch attempts -> no evidence
            frac = d_fail / d_att
            if not self.breaker_open and frac >= thr:
                self.breaker_open_shards[:] = True
                self.counters["breaker_trips"] += 1
            elif self.breaker_open and frac <= thr * hys:
                self.breaker_open_shards[:] = False
            return
        # per-shard: evidence, trip and recovery are all column-local
        evidence = d[1] > 0
        frac = d[0] / np.maximum(d[1], 1.0)
        opening = evidence & ~self.breaker_open_shards & (frac >= thr)
        if opening.any():
            self.breaker_open_shards |= opening
            self.counters["breaker_trips"] += int(opening.sum())
        closing = (evidence & self.breaker_open_shards
                   & (frac <= thr * hys))
        self.breaker_open_shards &= ~closing

    def _wait_ready(self, rows):
        """Block on a device result, with a watchdog: a wedged device call
        raises ``TimeoutError`` after ``watchdog_s`` instead of hanging
        the serving loop forever."""
        wd = self.cfg.watchdog_s
        if wd <= 0 or rows.is_ready():
            rows.block_until_ready()
            return
        deadline = time.time() + wd
        while not rows.is_ready():
            if time.time() >= deadline:
                raise TimeoutError(
                    f"serving watchdog: in-flight batch still not ready "
                    f"after {wd:.1f}s")
            time.sleep(5e-5)
        rows.block_until_ready()

    def _retire_one(self):
        e = self._inflight.popleft()
        # block only on the result actually being returned to a client
        self._wait_ready(e.rows)
        if e.served is None:
            self.latency.record(e.t_sched, time.time(), e.n)
            self.counters["served"] += e.n
            return
        cfg = self.cfg
        sv = np.asarray(jax.device_get(e.served))
        now = time.time()
        real = e.ids >= 0
        ok = real & sv
        if ok.any():
            lat = (now - e.t0s[ok]) * 1e6
            self.latency.record_us(lat)
            self.counters["served"] += int(ok.sum())
            if self.scfg is not None:
                # attribute serves to the owner shard so per-shard
                # breaker benchmarks can read healthy-shard goodput
                owners = e.ids[ok] // self.scfg.shard.num_objs
                np.add.at(self.served_per_shard, owners, 1)
            if cfg.deadline_us > 0:
                self.counters["deadline_misses"] += int(
                    (lat > cfg.deadline_us).sum())
        # unserved slots: bounded retry, else shed (counted) — a request
        # leaves the system exactly once, as served or as shed
        for i in np.nonzero(real & ~sv)[0]:
            if (cfg.max_retries > 0 and e.att[i] < cfg.max_retries
                    and len(self._retryq) < cfg.retry_queue_cap):
                self._retryq.append(
                    (int(e.ids[i]), float(e.t0s[i]), int(e.att[i]) + 1))
                self.counters["fetch_retries"] += 1
            else:
                self.counters["shed_requests"] += 1

    def drain(self):
        """Block on every in-flight batch (end of a workload)."""
        while self._inflight:
            self._retire_one()

    def flush_retries(self):
        """Drive the retry queue to empty with request-less ticks (end of a
        workload): each tick re-dispatches up to ``batch`` queued retries.
        Bounded — anything still unserved when attempts run out is shed."""
        guard = 4 * (self.cfg.max_retries + 2)
        while True:
            self.drain()
            if not self._retryq or guard <= 0:
                break
            self.submit(_EMPTY_IDS)
            guard -= 1
        while self._retryq:             # guard tripped: shed the leftovers
            self._retryq.popleft()
            self.counters["shed_requests"] += 1

    # -- synchronous convenience wrapper ------------------------------------

    def serve_batch(self, obj_ids: np.ndarray) -> jnp.ndarray:
        """Serve one batch synchronously; returns the rows."""
        rows = self.submit(obj_ids)
        self.drain()
        return rows

    def run(self, workload: Iterable[np.ndarray],
            offered_interarrival_s: float = 0.0) -> dict:
        """Drain a workload; optional pacing simulates offered load.

        With pacing, each batch's latency clock starts at its *scheduled*
        arrival time: serving earlier is impossible, serving later (the
        engine fell behind) counts the queueing delay — reproducing the
        saturation knee of the paper's latency-throughput curves.

        Reports **goodput** (served requests / wall) next to raw
        throughput ((served + shed) / wall): under faults the two split —
        shed requests leave the system fast but serve nobody."""
        t_run0 = time.time()
        next_arrival = time.time()
        for batch in workload:
            if offered_interarrival_s:
                t_sched = next_arrival
                # retire finished batches while waiting for the next
                # arrival, so recorded latency tracks device completion
                # even when the engine is under-loaded
                while True:
                    now = time.time()
                    if now >= next_arrival:
                        break
                    if self._inflight and self._inflight[0].rows.is_ready():
                        self._retire_one()
                        continue
                    time.sleep(min(2e-4, next_arrival - now))
                next_arrival += offered_interarrival_s
            else:
                t_sched = None
            self.submit(batch, t_sched=t_sched)
        self.drain()
        if self._robust:
            self.flush_retries()
        wall = max(time.time() - t_run0, 1e-9)
        per_shard = None
        if self.scfg is not None:
            raw = shardplane.stats_total(self.state)
            pf = shardplane.paging_fraction(self.scfg, self.state)
            # per-shard failure attribution: the plane already counts
            # fetch_failures on the owner shard that performed the fetch,
            # so a single-shard outage shows up on exactly one entry
            per_shard = [int(x) for x in np.asarray(
                jax.device_get(self.state.stats.fetch_failures))]
        else:
            raw = self.state.stats
            pf = plane_lib.paging_fraction(self.pcfg, self.state)
        stats = {k: int(v) for k, v in
                 jax.device_get(raw)._asdict().items()}
        served = self.counters["served"]
        finished = served + self.counters["shed_requests"]
        report = {"latency": self.latency.summary(), "stats": stats,
                  "paging_fraction": float(pf),
                  "counters": dict(self.counters),
                  "goodput_rps": served / wall,
                  "throughput_rps": finished / wall}
        if per_shard is not None:
            report["fetch_failures_per_shard"] = per_shard
            # egress (writeback) failures land on the shard whose slab the
            # write targeted — the breaker never reads these (fetch-only),
            # so a write-side brownout is visible here even if no trip fires
            report["egress_failures_per_shard"] = [int(x) for x in np.asarray(
                jax.device_get(self.state.stats.egress_failures))]
            report["served_per_shard"] = [int(x)
                                          for x in self.served_per_shard]
        return report
