"""Serving engine: continuous batching over the Atlas plane.

The engine serves key-value GET/SET requests against a far-memory-resident
object store managed by one of the three data planes (hybrid / paging-only
/ object-only) — the Memcached/WebService analogue used by the latency
benchmarks (paper §5.3).  Requests arrive on a queue with offered-load
pacing; the engine drains them in fixed-size batches (continuous
batching), tracks per-request latency, and periodically runs plane
maintenance (evacuation) exactly like Atlas's concurrent evacuator.

Dispatch is **plan-then-execute, double-buffered** (``dispatch=
"pipelined"``, the default): each batch is submitted as two device calls —
``plan_access`` (vectorized classification/dedup; its output shapes depend
only on the batch size) and ``execute_access`` (the data movement).  The
host never blocks at submit time: it enqueues batch N+1's plan + execute
while batch N is still running on device, and only blocks on the oldest
in-flight result once ``pipeline_depth`` batches are outstanding (or when
a caller explicitly asks for rows).  ``dispatch="sync"`` retires every
batch immediately — the serial engine the pipelined one is benchmarked
against; both produce bit-identical rows and plane state
(tests/test_serving.py).

Latency accounting: a request's latency is charged from its *scheduled
arrival time* (the offered-load pacing clock), not from when the engine
got around to serving it — under saturation the queueing delay is real
latency and is measured as such (the saturation knee of the paper's
latency-throughput curves).

Every plane runs on the plan-then-execute batch ingress engine
(``repro.core.batch``); ``EngineConfig.mode="reference"`` swaps in the
scalar oracle executor for debugging and equivalence runs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, plane as plane_lib, shardplane
from repro.core.layout import PlaneConfig
from repro.core import state as state_lib


@dataclasses.dataclass
class EngineConfig:
    plane: str = "hybrid"           # hybrid | paging | object
    batch: int = 64                 # requests per engine tick
    evac_every: int = 64            # hybrid-plane evacuation period (ticks)
    reclaim_free_target: int = 2    # object plane
    mode: str = "batch"             # plan-then-execute engine | "reference" oracle
    dispatch: str = "pipelined"     # "pipelined" double-buffer | "sync"
    pipeline_depth: int = 2         # max in-flight batches before blocking
    # Background evacuation: 0 = one foreground max_pages=16 compaction
    # every evac_every ticks (the pre-slice behavior); >0 = roughly the
    # foreground round's 16-page budget sliced into evac_budget-page
    # plan+execute calls spread evenly across the round's dispatch gaps
    # (ceil(16/budget) slices per round), so no single batch carries a
    # multi-page compaction on its critical path.  Access bits clear once
    # per round, on its first slice — the sliced round's "end of each
    # evacuation".
    evac_budget: int = 0
    # Epoch governor: advance_epoch every this many ticks (hybrid plane;
    # 0 = off).  Dispatched async like everything else.
    epoch_every: int = 0
    # Load-aware epoch scheduling: close an epoch once the plane has moved
    # this many bytes (paging + object traffic) since the last one (0 =
    # off).  A wall-clock tick schedule under-profiles churn bursts and
    # over-profiles idle stretches; the watermark keys the governor to the
    # traffic that actually moves its thresholds.  ``epoch_every`` stays on
    # as the idle-time fallback.  The probe is an async device read polled
    # with ``is_ready()`` so pipelined dispatch never blocks on it.
    epoch_watermark_bytes: int = 0
    # Sharded far tier: partition the plane over this many devices (1 =
    # the single-device plane).  ``batch`` splits evenly across shards
    # (each shard sources batch/shards requests per tick) and access runs
    # the round-based exchange of repro.core.shardplane — on a ``far``
    # mesh when the Engine gets one, else on the vmap oracle.
    shards: int = 1
    # Per-(src, dst) id budget per exchange round (0 = auto: one round,
    # budget = batch/shards, nothing ever spills).
    shard_budget: int = 0


class LatencyTracker:
    def __init__(self):
        self.lat_us: list[float] = []

    def record(self, t_in: float, t_out: float, n: int):
        dt = (t_out - t_in) * 1e6
        self.lat_us.extend([dt] * n)

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.lat_us, p)) if self.lat_us else 0.0

    def summary(self) -> dict:
        if not self.lat_us:
            return {}
        a = np.asarray(self.lat_us)
        return {"p50_us": float(np.percentile(a, 50)),
                "p90_us": float(np.percentile(a, 90)),
                "p99_us": float(np.percentile(a, 99)),
                "mean_us": float(a.mean()), "n": len(a)}


class Engine:
    """Continuous-batching serving engine (one device).

    ``submit`` enqueues one batch (plan + execute device calls) and returns
    the result as an async array; ``drain`` blocks on everything still in
    flight.  ``serve_batch`` is the synchronous convenience wrapper
    (submit + drain + return rows)."""

    def __init__(self, cfg: EngineConfig, pcfg: PlaneConfig,
                 initial: jnp.ndarray, mesh=None):
        self.cfg = cfg
        self.pcfg = pcfg
        self.scfg = None
        sharded = cfg.shards > 1
        epoch_on = (cfg.plane == "hybrid"
                    and (cfg.epoch_every > 0 or cfg.epoch_watermark_bytes > 0))
        # memoized jit entry points: engines sharing a PlaneConfig share one
        # compiled executable per op (continuous batching spins up several)
        self._plan = self._exec = self._access = None
        self._evac = self._epoch = self._traffic = None
        self._evac_slice = self._evac_slice_clear = None
        if sharded:
            assert cfg.batch % cfg.shards == 0, (
                f"batch={cfg.batch} must split evenly over "
                f"{cfg.shards} shards")
            self.scfg = scfg = shardplane.make_config(
                pcfg, cfg.shards, cfg.batch // cfg.shards,
                cfg.shard_budget or None, plane=cfg.plane)
            self.state = shardplane.create(scfg, initial)
            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec
                self.state = jax.device_put(self.state, jax.tree.map(
                    lambda _: NamedSharding(mesh, PartitionSpec("far")),
                    self.state))
            # fused access: the exchange already interleaves plan+execute
            # per round, so there is no host-visible plan/execute split
            self._access = shardplane.jitted_access(scfg, cfg.mode, mesh)
            if cfg.plane == "hybrid":
                self._evac = shardplane.jitted_evacuate(scfg, mesh=mesh)
                if cfg.evac_budget > 0:
                    self._evac_slice = shardplane.jitted_evacuate(
                        scfg, max_pages=cfg.evac_budget, clear_access=False,
                        mesh=mesh)
                    self._evac_slice_clear = shardplane.jitted_evacuate(
                        scfg, max_pages=cfg.evac_budget, clear_access=True,
                        mesh=mesh)
                if epoch_on:
                    self._epoch = shardplane.jitted_advance_epoch(scfg, mesh)
            tcfg = scfg.shard
        elif cfg.plane == "hybrid":
            self.state = state_lib.create(pcfg, initial)
            self._plan = plane_lib.jitted_plan_access(pcfg)
            self._exec = plane_lib.jitted_execute_access(pcfg, cfg.mode)
            self._evac = plane_lib.jitted_evacuate(pcfg)
            if cfg.evac_budget > 0:
                # background slices: each is plan_evacuate+execute_evacuate
                # composed into ONE async device call (a two-call split
                # only pays extra dispatch overhead when plan and execute
                # land in the same gap anyway); same 16-page budget per
                # evac_every round as the foreground call
                self._evac_slice = plane_lib.jitted_evacuate(
                    pcfg, max_pages=cfg.evac_budget, clear_access=False)
                self._evac_slice_clear = plane_lib.jitted_evacuate(
                    pcfg, max_pages=cfg.evac_budget, clear_access=True)
            if epoch_on:
                self._epoch = plane_lib.jitted_advance_epoch(pcfg)
            tcfg = pcfg
        elif cfg.plane == "paging":
            self.state = state_lib.create(pcfg, initial)
            self._plan = baselines.jitted_plan_paging(pcfg)
            self._exec = baselines.jitted_execute_paging(pcfg, cfg.mode)
            tcfg = pcfg
        elif cfg.plane == "object":
            self.state = state_lib.create(pcfg, initial)
            self._plan = baselines.jitted_plan_object(pcfg)
            self._exec = baselines.jitted_execute_object(pcfg, cfg.mode)
            tcfg = pcfg
        else:
            raise ValueError(cfg.plane)
        if self._evac_slice is not None:
            slices = -(-16 // cfg.evac_budget)          # ceil(16/budget)
            self._evac_slice_period = max(1, cfg.evac_every // slices)
            self._evac_round = 0        # last round whose access-clear ran
        if self._epoch is not None and cfg.epoch_watermark_bytes > 0:
            # bytes moved (paging + object ingress) since the last epoch —
            # the same deltas advance_epoch profiles; sharded states sum
            # elementwise over the stacked [S] counters
            pb, rb = float(tcfg.page_bytes), float(tcfg.row_bytes)
            self._traffic = jax.jit(lambda s: jnp.sum(
                (s.stats.page_ins - s.epoch_page_ins).astype(jnp.float32)
                * pb
                + (s.stats.obj_ins - s.epoch_obj_ins).astype(jnp.float32)
                * rb))
        self._probe = None              # in-flight traffic watermark read
        self.latency = LatencyTracker()
        self.ticks = 0
        self._inflight: deque = deque()     # (t_sched, rows, n) oldest-first
        # warm the compiled paths so the first request doesn't pay jit time
        if sharded:
            warm = jnp.zeros((cfg.shards, cfg.batch // cfg.shards),
                             jnp.int32)
            self.state, _ = self._access(self.state, warm)
        else:
            warm = jnp.zeros((cfg.batch,), jnp.int32)
            self.state, _ = self._exec(self.state, warm,
                                       self._plan(self.state, warm))
        if self._evac is not None:
            self.state = self._evac(self.state)
        if self._evac_slice is not None:
            # compile-cache the background-slice pair (results discarded)
            jax.block_until_ready(self._evac_slice(self.state))
            jax.block_until_ready(self._evac_slice_clear(self.state))
        if self._epoch is not None:
            jax.block_until_ready(self._epoch(self.state))
        if self._traffic is not None:
            jax.block_until_ready(self._traffic(self.state))
        self.state = self.state._replace(
            stats=jax.tree.map(jnp.zeros_like, self.state.stats),
            epoch_page_ins=jnp.zeros_like(self.state.epoch_page_ins),
            epoch_obj_ins=jnp.zeros_like(self.state.epoch_obj_ins))

    # -- pipelined dispatch -------------------------------------------------

    def submit(self, obj_ids: np.ndarray, t_sched: float | None = None):
        """Enqueue one batch; returns its rows as an async device array.

        ``t_sched``: the batch's scheduled arrival time (latency is charged
        from here; defaults to now).  Blocks only when more than
        ``pipeline_depth`` batches are in flight (back-pressure), never on
        the batch being submitted."""
        t_sched = time.time() if t_sched is None else t_sched
        # opportunistic retirement: anything already finished on device is
        # recorded now, so recorded latency tracks actual completion rather
        # than when back-pressure forces a block
        while self._inflight and self._inflight[0][1].is_ready():
            self._retire_one()
        ids = jnp.asarray(obj_ids, jnp.int32)
        n = len(obj_ids)
        if self._access is not None:
            # sharded far tier: the batch splits evenly across source
            # shards; short batches pad with the engine's negative-id
            # no-ops (fixed shapes keep one compiled program)
            S, R = self.cfg.shards, self.cfg.batch // self.cfg.shards
            if n < self.cfg.batch:
                ids = jnp.concatenate(
                    [ids, jnp.full((self.cfg.batch - n,), -1, jnp.int32)])
            self.state, out = self._access(self.state, ids.reshape(S, R))
            rows = out.reshape(self.cfg.batch, -1)[:n]
        else:
            # two async device calls: the plan dispatch is what a sharded
            # deployment runs host-side / on a prefetch stream
            plan = self._plan(self.state, ids)
            self.state, rows = self._exec(self.state, ids, plan)
        self._inflight.append((t_sched, rows, n))
        self.ticks += 1
        if self._evac is not None:
            if self.cfg.evac_budget > 0:
                # background evacuation: the foreground round's 16-page
                # budget rides in as evac_budget-page slices spread evenly
                # across the round's dispatch gaps (async device calls —
                # the host moves on to batch N+1 immediately); the
                # access-bit round closes on the evac_every boundary,
                # where the foreground mode used to pay the whole
                # compaction at once
                if self.ticks % self._evac_slice_period == 0:
                    # access bits clear once per evac_every round: on the
                    # first slice of each new round (period need not
                    # divide evac_every)
                    round_id = self.ticks // self.cfg.evac_every
                    if round_id > self._evac_round:
                        self._evac_round = round_id
                        self.state = self._evac_slice_clear(self.state)
                    else:
                        self.state = self._evac_slice(self.state)
            elif self.ticks % self.cfg.evac_every == 0:
                self.state = self._evac(self.state)
        if self._epoch is not None and self._epoch_due():
            self.state = self._epoch(self.state)
            self._probe = None          # watermark restarts from the epoch
        limit = 0 if self.cfg.dispatch == "sync" else self.cfg.pipeline_depth
        while len(self._inflight) > limit:
            self._retire_one()
        return rows

    def _epoch_due(self) -> bool:
        """Load-aware epoch schedule: the tick period (``epoch_every``) is
        the fallback; the byte watermark fires as soon as an async traffic
        probe reads past ``epoch_watermark_bytes`` — churn bursts advance
        epochs faster than the wall-clock schedule, idle stretches don't
        churn the governor.  Pipelined dispatch never blocks here: the
        probe is polled with ``is_ready()`` and acted on a tick late."""
        cfg = self.cfg
        if cfg.epoch_every > 0 and self.ticks % cfg.epoch_every == 0:
            return True
        if self._traffic is None:
            return False
        if self._probe is None:
            self._probe = self._traffic(self.state)
            if cfg.dispatch != "sync":
                return False            # poll on a later tick
        if cfg.dispatch == "sync" or self._probe.is_ready():
            due = float(self._probe) >= cfg.epoch_watermark_bytes
            self._probe = None
            return due
        return False

    def _retire_one(self):
        t_sched, rows, n = self._inflight.popleft()
        # block only on the result actually being returned to a client
        rows.block_until_ready()
        self.latency.record(t_sched, time.time(), n)

    def drain(self):
        """Block on every in-flight batch (end of a workload)."""
        while self._inflight:
            self._retire_one()

    # -- synchronous convenience wrapper ------------------------------------

    def serve_batch(self, obj_ids: np.ndarray) -> jnp.ndarray:
        """Serve one batch synchronously; returns the rows."""
        rows = self.submit(obj_ids)
        self.drain()
        return rows

    def run(self, workload: Iterable[np.ndarray],
            offered_interarrival_s: float = 0.0) -> dict:
        """Drain a workload; optional pacing simulates offered load.

        With pacing, each batch's latency clock starts at its *scheduled*
        arrival time: serving earlier is impossible, serving later (the
        engine fell behind) counts the queueing delay — reproducing the
        saturation knee of the paper's latency-throughput curves."""
        next_arrival = time.time()
        for batch in workload:
            if offered_interarrival_s:
                t_sched = next_arrival
                # retire finished batches while waiting for the next
                # arrival, so recorded latency tracks device completion
                # even when the engine is under-loaded
                while True:
                    now = time.time()
                    if now >= next_arrival:
                        break
                    if self._inflight and self._inflight[0][1].is_ready():
                        self._retire_one()
                        continue
                    time.sleep(min(2e-4, next_arrival - now))
                next_arrival += offered_interarrival_s
            else:
                t_sched = None
            self.submit(batch, t_sched=t_sched)
        self.drain()
        if self.scfg is not None:
            raw = shardplane.stats_total(self.state)
            pf = shardplane.paging_fraction(self.scfg, self.state)
        else:
            raw = self.state.stats
            pf = plane_lib.paging_fraction(self.pcfg, self.state)
        stats = {k: int(v) for k, v in
                 jax.device_get(raw)._asdict().items()}
        return {"latency": self.latency.summary(), "stats": stats,
                "paging_fraction": float(pf)}
