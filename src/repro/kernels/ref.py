"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>_ref`` matches the semantics of the corresponding kernel in
``<name>.py``; the kernel tests sweep shapes/dtypes and assert allclose
against these.  The production ``ops`` wrappers fall back to these on
non-TPU backends (interpret-mode Pallas is used for validation only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# object gather / scatter (runtime-path ingress / egress)
# --------------------------------------------------------------------------

def gather_rows_ref(pool: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """pool: [N, D]; idx: [R] int32 -> [R, D].  Negative idx yields zeros
    (masked slots in a fetch list)."""
    rows = pool[jnp.maximum(idx, 0)]
    return jnp.where((idx >= 0)[:, None], rows, 0).astype(pool.dtype)


def scatter_rows_ref(pool: jnp.ndarray, idx: jnp.ndarray,
                     rows: jnp.ndarray) -> jnp.ndarray:
    """Write rows[i] -> pool[idx[i]] where idx[i] >= 0 (idx entries unique)."""
    safe = jnp.maximum(idx, 0)
    masked = jnp.where((idx >= 0)[:, None], rows.astype(pool.dtype), pool[safe])
    return pool.at[safe].set(masked)


# --------------------------------------------------------------------------
# card access table update (always-on profiling)
# --------------------------------------------------------------------------

def cat_update_ref(cat_bits: jnp.ndarray, vaddrs: jnp.ndarray,
                   page_objs: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Set card bits for touched vaddrs in a packed bitmap.

    cat_bits: [V, W] uint32 where W = ceil(page_objs/32);
    vaddrs: [R] int32 (negative = skip).
    Returns (new_bits, car[V] float32) with CAR = popcount/page_objs."""
    V, W = cat_bits.shape
    v = vaddrs // page_objs
    slot = vaddrs % page_objs
    word, bit = slot // 32, slot % 32
    valid = vaddrs >= 0
    upd = jnp.where(valid, jnp.uint32(1) << bit.astype(jnp.uint32), jnp.uint32(0))
    pos = jnp.where(valid, v * W + word, 0)
    # duplicate positions must OR together: sequential scatter-OR
    flat_new = jnp.zeros((V * W,), jnp.uint32)

    def body(i, m):
        return m.at[pos[i]].set(m[pos[i]] | upd[i])

    flat_new = jax.lax.fori_loop(0, vaddrs.shape[0], body, flat_new)
    bits = cat_bits | flat_new.reshape(V, W)
    pc = _popcount32(bits).sum(axis=1).astype(jnp.float32)
    return bits, pc / jnp.float32(page_objs)


def _popcount32(x: jnp.ndarray) -> jnp.ndarray:
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def cat_decay_ref(cat: jnp.ndarray, car_ema: jnp.ndarray, alloc: jnp.ndarray,
                  decay: float) -> jnp.ndarray:
    """Epoch CAR EMA: cat [V, P] int32 (0/1), car_ema [V] f32, alloc [V] i32
    -> new_ema [V] f32 = decay*ema + (1-decay)*popcount/max(alloc, 1)."""
    car = cat.astype(jnp.float32).sum(axis=1) / jnp.maximum(alloc, 1)
    return jnp.float32(decay) * car_ema + jnp.float32(1.0 - decay) * car


# --------------------------------------------------------------------------
# paged decode attention (the paging-path consumer)
# --------------------------------------------------------------------------

def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, page_table: jnp.ndarray,
                        page_lens: jnp.ndarray) -> jnp.ndarray:
    """Decode attention over a paged KV store.

    q:          [B, H, Dh]           (one new token per sequence)
    k_pages:    [KVH, F, P, Dh]      (frame pool, per kv head)
    v_pages:    [KVH, F, P, Dh]
    page_table: [B, NP] int32        (frame id per table column, -1 unused)
    page_lens:  [B, NP] int32        (valid rows in each column's frame)
    returns     [B, H, Dh]

    H = KVH * G (GQA groups).  Softmax over the first ``page_lens[b, j]``
    rows of each referenced frame (decode attention is permutation-
    invariant over past KV, so columns may be any page subset and rows may
    be packed)."""
    B, H, Dh = q.shape
    KVH, F, P, _ = k_pages.shape
    NP = page_table.shape[1]
    G = H // KVH

    def per_seq(qb, pt, pl):
        # gather pages: [KVH, NP, P, Dh] -> [KVH, NP*P, Dh]
        safe = jnp.maximum(pt, 0)
        k = k_pages[:, safe].reshape(KVH, NP * P, Dh)
        v = v_pages[:, safe].reshape(KVH, NP * P, Dh)
        qg = qb.reshape(KVH, G, Dh)
        scores = jnp.einsum("kgd,ksd->kgs", qg.astype(jnp.float32),
                            k.astype(jnp.float32))
        scores *= 1.0 / jnp.sqrt(jnp.float32(Dh))
        row = jnp.tile(jnp.arange(P), NP)
        valid = (row < jnp.repeat(pl, P)) & jnp.repeat(pt >= 0, P)
        scores = jnp.where(valid[None, None, :], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("kgs,ksd->kgd", w, v.astype(jnp.float32))
        # card profiling signal: a row is "used" if its weight is above the
        # within-page mean (flat pages mark everything -> paging; skewed
        # pages mark the few heavy rows -> runtime)
        wp = w.reshape(KVH, G, NP, P)
        page_mass = wp.sum(-1, keepdims=True)
        used = (wp * P > page_mass).any(axis=(0, 1))     # [NP, P]
        used &= valid.reshape(NP, P)
        return out.reshape(H, Dh).astype(q.dtype), used

    return jax.vmap(per_seq)(q, page_table, page_lens)


# --------------------------------------------------------------------------
# evacuation compaction (hot/cold segregation)
# --------------------------------------------------------------------------

def compact_rows_ref(frames: jnp.ndarray, src: jnp.ndarray,
                     dst_page: jnp.ndarray, dst_rows: jnp.ndarray
                     ) -> jnp.ndarray:
    """Assemble destination pages from scattered source rows.

    frames:   [F, P, D] row pool
    src:      [M, P] int32 flat row index (frame*P + slot) per dst slot, -1 keep
    dst_page: [M] int32 destination frame per assembled page
    dst_rows: unused placeholder (API symmetry)
    Moves are disjoint: no src row is also a dst slot."""
    F, P, D = frames.shape
    flat = frames.reshape(F * P, D)
    gathered = flat[jnp.maximum(src, 0)]                      # [M, P, D]
    keep = frames[jnp.maximum(dst_page, 0)]                   # [M, P, D]
    page = jnp.where((src >= 0)[..., None], gathered, keep)
    valid = dst_page >= 0
    out = frames.at[jnp.maximum(dst_page, 0)].set(
        jnp.where(valid[:, None, None], page, keep))
    return out


# --------------------------------------------------------------------------
# sparse-attention page scoring (offload-space computation)
# --------------------------------------------------------------------------

def page_scores_ref(q: jnp.ndarray, kmax: jnp.ndarray, kmin: jnp.ndarray
                    ) -> jnp.ndarray:
    """Quest-style upper-bound page scores against far-resident summaries.

    q:    [B, H, Dh]
    kmax: [KVH, NP, Dh]  per-page elementwise max of keys
    kmin: [KVH, NP, Dh]  per-page elementwise min of keys
    returns [B, KVH, NP] float32: sum_d max(q*kmax, q*kmin), max over the
    GQA group."""
    B, H, Dh = q.shape
    KVH = kmax.shape[0]
    G = H // KVH
    qg = q.reshape(B, KVH, G, Dh).astype(jnp.float32)
    # per-dim bound: max(q_d * kmax_d, q_d * kmin_d), summed over d
    ub = jnp.maximum(qg[:, :, :, None, :] * kmax.astype(jnp.float32)[None, :, None],
                     qg[:, :, :, None, :] * kmin.astype(jnp.float32)[None, :, None])
    return ub.sum(-1).max(axis=2)
