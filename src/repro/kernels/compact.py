"""Evacuation compaction kernel: assemble destination pages from scattered
live rows (hot/cold segregated by the caller's move plan).

Each grid step builds one slot of a destination page by DMA-ing the source
row selected by the scalar-prefetched move plan — the TPU analogue of the
evacuator's copying loop.  Masked slots (-1) are zero-filled (fresh log
pages).  The caller scatters the assembled pages into the frame pool.

Shapes: pool [N, D] (N = F * P flat rows), plan [M * P] int32 flat row ids
        -> pages [M, P, D]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(plan_ref, pool_ref, out_ref, *, page_objs: int):
    m = pl.program_id(0)
    p = pl.program_id(1)
    valid = plan_ref[m * page_objs + p] >= 0
    row = jnp.where(valid, pool_ref[...], jnp.zeros_like(pool_ref))
    out_ref[...] = row.reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("page_objs", "interpret"))
def compact_pages(pool: jnp.ndarray, plan: jnp.ndarray, *,
                  page_objs: int, interpret: bool = False) -> jnp.ndarray:
    """pool [N, D], plan [M*P] -> assembled pages [M, P, D]."""
    N, D = pool.shape
    P = page_objs
    M = plan.shape[0] // P

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M, P),
        in_specs=[
            pl.BlockSpec((1, D),
                         lambda m, p, plan: (jnp.maximum(plan[m * P + p], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda m, p, plan: (m, p, 0)),
    )
    kernel = functools.partial(_kernel, page_objs=P)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, P, D), pool.dtype),
        interpret=interpret,
    )(plan, pool)
