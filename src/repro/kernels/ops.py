"""Jitted production wrappers for the Pallas kernels.

Dispatch policy (``impl``):
  * ``"auto"``      — Pallas on TPU backends, jnp reference otherwise.  The
    reference path is what the CPU-backend multi-pod dry-run lowers (Pallas
    TPU custom calls cannot lower on CPU); on a real pod the Pallas path is
    taken.  Both compute identical values (asserted by the kernel tests).
  * ``"pallas"``    — force the compiled Pallas kernel.
  * ``"interpret"`` — Pallas kernel body executed in interpret mode
    (kernel-correctness validation on CPU).
  * ``"ref"``       — force the pure-jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .cat_decay import cat_decay as _cat_decay_pallas
from .cat_update import cat_update as _cat_pallas
from .compact import compact_pages as _compact_pallas
from .gather_objects import gather_rows as _gather_pallas
from .paged_attention import paged_attention as _paged_attn_pallas
from .topk_pages import page_scores as _scores_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def gather_rows(pool, idx, *, impl="auto", masked=True):
    """pool [N, D], idx [R] -> [R, D].  With ``masked`` (default) negative
    indices yield zero rows; ``masked=False`` skips the zero-fill pass (the
    jnp path's extra select over the result) when the caller never consumes
    rows at negative indices — e.g. when they are dropped by a downstream
    masked scatter."""
    m = _mode(impl)
    if m == "ref":
        if not masked:
            return pool[jnp.maximum(idx, 0)]
        return ref.gather_rows_ref(pool, idx)
    return _gather_pallas(pool, idx, interpret=(m == "interpret"))


def gather_pages(slab, page_ids, perm=None, *, impl="auto", masked=True):
    """Multi-head page assembly in ONE batched row gather.

    slab [KVH, S, P, Dh]; page_ids [N] int32 (-1 = masked, yields zero
    pages); optional perm [N, P] row permutation applied to each fetched
    page (the runtime path's hot-row packing) -> [KVH, N, P, Dh].

    The pool is viewed page-granularly ([KVH*S, P*Dh]) so each fetched
    page is ONE ``gather_rows`` row — one DMA descriptor per page per head,
    all heads in a single kernel launch.  The packing permutation runs
    locally on the fetched tile (egress from the far tier is always
    page-granular; packing is a local-space relayout)."""
    KVH, S, P, Dh = slab.shape
    N = page_ids.shape[0]
    base = jnp.arange(KVH, dtype=jnp.int32)[:, None] * S
    idx = jnp.where(page_ids[None] >= 0, base + page_ids[None], -1)
    pages = gather_rows(slab.reshape(KVH * S, P * Dh), idx.reshape(-1),
                        impl=impl, masked=masked).reshape(KVH, N, P, Dh)
    if perm is not None:
        pages = jnp.take_along_axis(pages, perm[None, :, :, None], axis=2)
    return pages


def cat_update(cat_bits, vaddrs, *, page_objs: int, impl="auto"):
    """Returns (bits, car[V] float32)."""
    m = _mode(impl)
    if m == "ref":
        return ref.cat_update_ref(cat_bits, vaddrs, page_objs)
    bits, counts = _cat_pallas(cat_bits, vaddrs, page_objs=page_objs,
                               interpret=(m == "interpret"))
    return bits, counts[:, 0].astype(jnp.float32) / jnp.float32(page_objs)


def cat_decay(cat, car_ema, alloc, *, decay: float, impl="auto"):
    """Epoch-advance CAR EMA.  cat [V, P] bool, car_ema [V] f32,
    alloc [V] i32 -> new_ema [V] f32 (see kernels.cat_decay)."""
    m = _mode(impl)
    cat_i = cat.astype(jnp.int32)
    if m == "ref":
        return ref.cat_decay_ref(cat_i, car_ema, alloc, decay)
    out = _cat_decay_pallas(cat_i, car_ema[:, None], alloc[:, None],
                            decay=decay, interpret=(m == "interpret"))
    return out[:, 0]


def paged_attention(q, k_pages, v_pages, page_table, page_lens, *, impl="auto"):
    """q [B, H, Dh]; k/v_pages [KVH, F, P, Dh]; page_table [B, NP];
    page_lens [B, NP] (valid rows per column).

    Returns (out [B, H, Dh], row_used [B, NP, P] bool) — ``row_used`` is the
    card-profiling signal: rows whose attention weight exceeded the
    within-page mean."""
    m = _mode(impl)
    if m == "ref":
        return ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                       page_lens)
    B, H, Dh = q.shape
    KVH = k_pages.shape[0]
    G = H // KVH
    out, used = _paged_attn_pallas(q.reshape(B, KVH, G, Dh), k_pages, v_pages,
                                   page_table.reshape(-1),
                                   page_lens.reshape(-1),
                                   interpret=(m == "interpret"))
    return out.reshape(B, H, Dh), used.astype(bool).any(axis=1)


def lengths_to_page_lens(lengths, num_pages: int, page_tokens: int):
    """Dense layout helper: [B] total lengths -> [B, NP] per-page rows."""
    starts = jnp.arange(num_pages) * page_tokens
    return jnp.clip(lengths[:, None] - starts[None, :], 0, page_tokens
                    ).astype(jnp.int32)


def compact_pages(pool, plan, *, page_objs: int, impl="auto"):
    """pool [N, D], plan [M*P] flat row ids -> assembled pages [M, P, D]."""
    m = _mode(impl)
    if m == "ref":
        D = pool.shape[-1]
        M = plan.shape[0] // page_objs
        return ref.gather_rows_ref(pool, plan).reshape(M, page_objs, D)
    return _compact_pallas(pool, plan, page_objs=page_objs,
                           interpret=(m == "interpret"))


def page_scores(q, kmax, kmin, *, impl="auto"):
    """q [B, H, Dh] -> scores [B, KVH, NP] float32."""
    m = _mode(impl)
    if m == "ref":
        return ref.page_scores_ref(q, kmax, kmin)
    B, H, Dh = q.shape
    KVH, NP, _ = kmax.shape
    G = H // KVH
    blk = NP if NP < 128 else 128
    while NP % blk:
        blk //= 2
    return _scores_pallas(q.reshape(B, KVH, G, Dh), kmax, kmin,
                          block_pages=blk, interpret=(m == "interpret"))


# --------------------------------------------------------------------------
# packed-payload layouts for the sharded exchange (repro.core.shardplane)
# --------------------------------------------------------------------------
# The exchange used to move ids, duplicate counts and served flags as
# separate collectives; these helpers fuse the side channels into ONE
# payload per direction so each round pays exactly two all_to_all hops.
# They are axis-agnostic (pure stack/concat on the trailing axes), so the
# same layout serves the per-shard [S, B] buffers inside shard_map and the
# stacked [S, S, B] buffers of the single-device oracle — fusing then
# splitting is bitwise lossless either way.

def fuse_ids_counts(ids, cnt):
    """ids [..., B] int32 + cnt [..., B] int32 -> [..., 2, B] payload."""
    return jnp.stack([ids, cnt], axis=-2)


def split_ids_counts(payload):
    """Inverse of :func:`fuse_ids_counts`."""
    return payload[..., 0, :], payload[..., 1, :]


def fuse_rows_flags(rows, flags):
    """rows [..., B, D] + flags [..., B] bool -> [..., B, D+1] payload.

    The bool rides as an extra 0/1 column in the row dtype — exact in
    every float format down to bf16, so the round-trip is lossless."""
    return jnp.concatenate(
        [rows, flags[..., None].astype(rows.dtype)], axis=-1)


def split_rows_flags(payload):
    """Inverse of :func:`fuse_rows_flags`."""
    return payload[..., :-1], payload[..., -1] > 0
