"""Sparse-attention page scoring: the offload-space computation.

Scores every KV page against the current queries using Quest-style
min/max key summaries WITHOUT fetching the pages themselves — the summaries
are tiny and stay local while the page data may be far-resident.  The
plane's sparse path then object-fetches only the top-k pages' rows.

score[b, h, n] = max_g sum_d max(q[b,h,g,d] * kmax[h,n,d],
                                 q[b,h,g,d] * kmin[h,n,d])

Shapes: q [B, KVH, G, Dh], kmax/kmin [KVH, NP, Dh] -> scores [B, KVH, NP]
(NP must be a multiple of the page block, default 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, kmax_ref, kmin_ref, out_ref):
    q = q_ref[0, 0].astype(jnp.float32)          # [G, Dh]
    kmax = kmax_ref[0].astype(jnp.float32)       # [NPB, Dh]
    kmin = kmin_ref[0].astype(jnp.float32)       # [NPB, Dh]
    # [G, NPB, Dh] elementwise upper bound, reduce over Dh then G
    hi = q[:, None, :] * kmax[None, :, :]
    lo = q[:, None, :] * kmin[None, :, :]
    ub = jnp.maximum(hi, lo).sum(axis=-1)        # [G, NPB]
    out_ref[0, 0] = jnp.max(ub, axis=0)          # [NPB]


@functools.partial(jax.jit, static_argnames=("block_pages", "interpret"))
def page_scores(q: jnp.ndarray, kmax: jnp.ndarray, kmin: jnp.ndarray, *,
                block_pages: int = 128, interpret: bool = False) -> jnp.ndarray:
    B, KVH, G, Dh = q.shape
    _, NP, _ = kmax.shape
    NPB = min(block_pages, NP)
    assert NP % NPB == 0, (NP, NPB)

    grid = (B, KVH, NP // NPB)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, n: (b, h, 0, 0)),
            pl.BlockSpec((1, NPB, Dh), lambda b, h, n: (h, n, 0)),
            pl.BlockSpec((1, NPB, Dh), lambda b, h, n: (h, n, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, NPB), lambda b, h, n: (b, h, n)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, NP), jnp.float32),
        interpret=interpret,
    )(q, kmax, kmin)
