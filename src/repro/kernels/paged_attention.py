"""Paged decode attention: the paging-path consumer of the tiered KV cache.

One new query token per sequence attends over a KV cache stored as *pages*
(frames) indirected through the plane's page table — the TPU-native analogue
of reading through the kernel's paging system.  Page-table entries are
scalar-prefetched so each logical page's HBM->VMEM DMA is issued ahead of
the compute (streamed, double-buffered by the Pallas pipeline).

Shapes:
    q           [B, KVH, G, Dh]   (H = KVH * G query heads, GQA)
    k_pages     [KVH, F, P, Dh]   frame pool
    v_pages     [KVH, F, P, Dh]
    page_table  [B * NP] int32    frame id per (seq, logical page), -1 unused
    lengths     [B] int32         live tokens per sequence
    out         [B, KVH, G, Dh]

Online-softmax accumulation in f32 VMEM scratch; grid (B, KVH, NP) with the
page dimension innermost so scratch carries across pages of one (seq, head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, plen_ref, q_ref, k_ref, v_ref, out_ref, used_ref,
            m_ref, l_ref, acc_ref, *, num_pages: int, page_objs: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = plen_ref[b * num_pages + j]
    frame = pt_ref[b * num_pages + j]
    valid_page = jnp.logical_and(frame >= 0, rows > 0)
    used_ref[...] = jnp.zeros(used_ref.shape, used_ref.dtype)

    @pl.when(valid_page)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, Dh]
        k = k_ref[0, 0].astype(jnp.float32)          # [P, Dh]
        v = v_ref[0, 0].astype(jnp.float32)          # [P, Dh]
        dh = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= jax.lax.rsqrt(jnp.float32(dh))          # [G, P]
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(row < rows, s, NEG_INF)

        m_prev = m_ref[...]                          # [G, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                       # [G, P]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        # card profiling: row used if its (unnormalized) weight exceeds the
        # within-page mean for any query of the group
        mass = jnp.sum(p, axis=1, keepdims=True)     # [G, 1]
        used = jnp.logical_and(p * page_objs > mass, row < rows)
        used_ref[...] = jnp.any(used, axis=0).reshape(
            used_ref.shape).astype(used_ref.dtype)

    @pl.when(j == num_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                    page_table: jnp.ndarray, page_lens: jnp.ndarray, *,
                    interpret: bool = False) -> jnp.ndarray:
    B, KVH, G, Dh = q.shape
    _, F, P, _ = k_pages.shape
    NP = page_table.shape[0] // B

    def _clamped(i, pt_ref):
        return jnp.maximum(pt_ref[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, NP),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P, Dh),
                         lambda b, h, j, pt, ln: (h, _clamped(b * NP + j, pt), 0, 0)),
            pl.BlockSpec((1, 1, P, Dh),
                         lambda b, h, j, pt, ln: (h, _clamped(b * NP + j, pt), 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P), lambda b, h, j, pt, ln: (b, h, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, num_pages=NP, page_objs=P)
    out, used = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KVH, G, Dh), q.dtype),
                   jax.ShapeDtypeStruct((B, KVH, NP, P), jnp.int8)],
        interpret=interpret,
    )(page_table, page_lens, q, k_pages, v_pages)
    return out, used
