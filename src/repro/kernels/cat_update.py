"""Card-access-table update kernel: the always-on profiling hot path.

Atlas's profiling must be cheap enough to leave on permanently (paper §1:
"always-on profiling").  This kernel ORs the card bits for a batch of
touched vaddrs into a packed uint32 bitmap and emits the per-page popcount
(numerator of the CAR) in the same pass.

Grid is over pages; each step scans the (small, scalar-prefetched) touch
list and ORs the bits that fall on its page — branch-free SIMD, no
scatter hazards from duplicate touches.

Shapes: cat_bits [V, W] uint32 (W = ceil(P/32)), vaddrs [R] int32 (-1 skip)
        -> (new_bits [V, W], popcount [V, 1] int32)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _popcount32(x):
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(idx_ref, bits_ref, out_bits_ref, count_ref, *,
            page_objs: int, num_touch: int):
    v = pl.program_id(0)
    W = bits_ref.shape[1]
    words = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

    def body(i, bits):
        va = idx_ref[i]
        pv = va // page_objs
        slot = va % page_objs
        hit = jnp.logical_and(va >= 0, pv == v)
        word, bit = slot // 32, slot % 32
        delta = jnp.where(jnp.logical_and(hit, words == word),
                          jnp.uint32(1) << bit.astype(jnp.uint32),
                          jnp.uint32(0))
        return bits | delta

    bits = jax.lax.fori_loop(0, num_touch, body, bits_ref[...])
    out_bits_ref[...] = bits
    count_ref[...] = jnp.sum(_popcount32(bits), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("page_objs", "interpret"))
def cat_update(cat_bits: jnp.ndarray, vaddrs: jnp.ndarray, *,
               page_objs: int, interpret: bool = False):
    V, W = cat_bits.shape
    R = vaddrs.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(V,),
        in_specs=[pl.BlockSpec((1, W), lambda v, idx: (v, 0))],
        out_specs=[pl.BlockSpec((1, W), lambda v, idx: (v, 0)),
                   pl.BlockSpec((1, 1), lambda v, idx: (v, 0))],
    )
    kernel = functools.partial(_kernel, page_objs=page_objs, num_touch=R)
    bits, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((V, W), jnp.uint32),
                   jax.ShapeDtypeStruct((V, 1), jnp.int32)],
        interpret=interpret,
    )(vaddrs, cat_bits)
    return bits, counts
