"""Runtime-path object gather: fetch R scattered rows from the far-tier pool.

This is the batched "object-in" data movement of the hybrid plane.  On TPU
the row indices are *scalar-prefetched* so each row's HBM->VMEM DMA is
issued ahead of the copy — the TPU-native replacement for AIFM's RDMA reads
of individual objects.

Layout: pool [N, D] (N = V*P rows of the slab), idx [R] int32 (-1 = masked),
out [R, D].  D must be a multiple of 128 (lane width); rows are blocked in
groups of ``rows_per_block`` on the sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, pool_ref, out_ref):
    # pool_ref: [1, D] block selected by the scalar-prefetched index;
    # out_ref:  [1, D] block at row i.
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    out_ref[...] = jnp.where(valid, pool_ref[...], jnp.zeros_like(pool_ref))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(pool: jnp.ndarray, idx: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    """Pallas object gather.  pool [N, D], idx [R] -> [R, D]."""
    N, D = pool.shape
    R = idx.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, idx_ref: (jnp.maximum(idx_ref[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, D), pool.dtype),
        interpret=interpret,
    )(idx, pool)
