"""Epoch-advance kernel: decayed card-access-rate EMA (always-on profiling).

Sibling of ``cat_update``: where that kernel folds a batch of touches INTO
the card table, this one folds the card table into the per-page CAR EMA at
an epoch boundary.  Each grid step reduces one page's card bits to the
epoch-window CAR (popcount / allocated cards) and blends it into the
running EMA:

    ema' = decay * ema + (1 - decay) * popcount(cat) / max(alloc, 1)

The epoch governor (``plane.advance_epoch``) recomputes every allocated
page's PSF from this decayed CAR — path selection adapts online instead of
waiting for a page-out — and the caller clears the card table to open the
next epoch window.

Shapes: cat [V, P] int32 (0/1 card bits), car_ema [V, 1] float32,
        alloc [V, 1] int32 -> new_ema [V, 1] float32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cat_ref, ema_ref, alloc_ref, out_ref, *, decay: float):
    cnt = jnp.sum(cat_ref[...].astype(jnp.float32), axis=1, keepdims=True)
    denom = jnp.maximum(alloc_ref[...], 1).astype(jnp.float32)
    car = cnt / denom
    out_ref[...] = jnp.float32(decay) * ema_ref[...] + \
        jnp.float32(1.0 - decay) * car


@functools.partial(jax.jit, static_argnames=("decay", "interpret"))
def cat_decay(cat: jnp.ndarray, car_ema: jnp.ndarray, alloc: jnp.ndarray, *,
              decay: float, interpret: bool = False) -> jnp.ndarray:
    """cat [V, P] int32, car_ema [V, 1] f32, alloc [V, 1] i32 -> [V, 1] f32."""
    V, P = cat.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(V,),
        in_specs=[pl.BlockSpec((1, P), lambda v: (v, 0)),
                  pl.BlockSpec((1, 1), lambda v: (v, 0)),
                  pl.BlockSpec((1, 1), lambda v: (v, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda v: (v, 0)),
    )
    kernel = functools.partial(_kernel, decay=decay)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((V, 1), jnp.float32),
        interpret=interpret,
    )(cat, car_ema, alloc)
