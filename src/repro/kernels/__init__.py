"""Pallas TPU kernels for the plane's compute hot spots.

Per-kernel modules hold the ``pl.pallas_call`` + BlockSpec implementations;
``ref.py`` holds the pure-jnp oracles; ``ops.py`` is the jitted dispatch
surface used by the rest of the framework.

Kernels:
  * ``gather_objects``  — runtime-path object ingress (row gather)
  * ``paged_attention`` — decode attention through the page table
  * ``cat_update``      — always-on card-table profiling + CAR popcount
  * ``compact``         — evacuator page assembly (hot/cold segregation)
  * ``topk_pages``      — offload-space page scoring for sparse attention
"""
from . import ops, ref
from .ops import (cat_update, compact_pages, gather_rows, page_scores,
                  paged_attention)

__all__ = ["ops", "ref", "cat_update", "compact_pages", "gather_rows",
           "page_scores", "paged_attention"]
