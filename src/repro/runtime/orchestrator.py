"""Distributed-training orchestrator: the control plane a 1000-node job
needs around the jitted step —

  * checkpoint/restart: periodic async saves, resume from ``latest()``,
    step-indexed data (no replay drift), emergency save on failure
  * failure handling: a pluggable ``FailureInjector`` simulates node loss;
    recovery = restore + (optionally) re-mesh (elastic)
  * straggler mitigation: per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are logged and counted — on real pods
    this signal drives backup-task dispatch / hot-spare swap; here it
    feeds the metrics the tests assert on
  * deterministic restart: the data stream is derived from the global step
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.core import faults


class FailureInjector:
    """Deterministic failure schedule for tests/drills.

    A host-side view over the plane-wide fault model
    (:class:`repro.core.faults.Schedule`): the legacy ``fail_at_steps``
    list becomes the schedule's explicit ``fail_at`` ticks, and a full
    ``schedule`` adds seeded per-step node loss (``fail_prob``) and
    outage windows — the same streams the serving engine and the chaos
    tests consume, so one seed describes a whole drill.  Each step fires
    at most once (a restarted step must not re-fail forever)."""

    def __init__(self, fail_at_steps=(),
                 schedule: Optional[faults.Schedule] = None):
        extra = tuple(int(s) for s in fail_at_steps)
        if schedule is None:
            schedule = faults.Schedule(fail_at=extra)
        elif extra:
            schedule = dataclasses.replace(
                schedule, fail_at=tuple(schedule.fail_at) + extra)
        self.schedule = schedule
        self.failures = 0
        self._fired: set = set()

    def check(self, step: int):
        step = int(step)
        if step in self._fired:
            return
        if self.schedule.fails(step):
            self._fired.add(step)
            self.failures += 1
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class OrchestratorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    ewma: float = 0.9


class Orchestrator:
    """Runs ``train_step`` with checkpointing, failure recovery and
    straggler accounting.

    ``train_step(state, batch) -> (state, metrics)`` where ``state`` is an
    arbitrary pytree containing the trainable state and ``batch_fn(step)``
    yields the (deterministic) batch for a global step."""

    def __init__(self, cfg: OrchestratorConfig, train_step: Callable,
                 batch_fn: Callable[[int], Any],
                 injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.injector = injector or FailureInjector()
        self.saver = ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
        self.metrics = {"steps": 0, "restarts": 0, "stragglers": 0,
                        "step_times": []}
        self._ewma_t = None

    # -- checkpoint/restart ------------------------------------------------
    def resume_or_init(self, init_state):
        step = ckpt_lib.latest(self.cfg.ckpt_dir)
        if step is None:
            return init_state, 0
        state, extra = ckpt_lib.restore(self.cfg.ckpt_dir, step, init_state)
        return state, int(extra.get("next_step", step))

    # -- main loop ----------------------------------------------------------
    def run(self, init_state, num_steps: int, *, max_restarts: int = 10):
        # host-side snapshot: the jitted step may donate the live state's
        # buffers, which would make ``init_state`` unusable as the restart
        # fallback after a failure
        init_host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 init_state)
        state, start = self.resume_or_init(init_host)
        step = start
        restarts = 0
        while step < num_steps:
            try:
                state, step = self._run_span(state, step, num_steps)
            except RuntimeError:
                # node failure: recover from the last checkpoint boundary —
                # but first let any in-flight async save land, or the
                # newest checkpoint stays an unpublished .tmp dir
                restarts += 1
                self.metrics["restarts"] = restarts
                if restarts > max_restarts:
                    raise
                self.saver.wait()
                state, step = self.resume_or_init(init_host)
        self.saver.save(step, state, extra={"next_step": step}, block=True)
        return state

    def _run_span(self, state, step, num_steps):
        while step < num_steps:
            batch = self.batch_fn(step)
            t0 = time.time()
            self.injector.check(step)
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.time() - t0
            self._track_time(dt)
            step += 1
            self.metrics["steps"] += 1
            if step % self.cfg.ckpt_every == 0:
                self.saver.save(step, state, extra={"next_step": step})
        return state, step

    def _track_time(self, dt: float):
        self.metrics["step_times"].append(dt)
        if self._ewma_t is None:
            self._ewma_t = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma_t:
            self.metrics["stragglers"] += 1
        self._ewma_t = self.cfg.ewma * self._ewma_t + (1 - self.cfg.ewma) * dt
