"""Deterministic synthetic data: step-indexed so a restarted job resumes
exactly where it left off (no replay / no skip drift) — the data-side half
of fault tolerance.

Token streams are generated per (step, shard) from a counter-based PRNG
(threefry), so any host can regenerate any step without coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # mixture weights for synthetic pattern families (zipf head + uniform)
    zipf_alpha: float = 1.1


def batch_for_step(cfg: DataConfig, step: int, *, with_labels: bool = True,
                   frontend: Optional[dict] = None) -> dict:
    """Deterministic batch for a global step (numpy host-side)."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    # zipf-ish token distribution (realistic rank-frequency)
    ranks = rng.zipf(cfg.zipf_alpha, size=(cfg.global_batch, cfg.seq_len))
    tokens = np.minimum(ranks - 1, cfg.vocab - 1).astype(np.int32)
    out = {"tokens": tokens}
    if with_labels:
        labels = np.concatenate([tokens[:, 1:],
                                 np.full((cfg.global_batch, 1), -1, np.int32)],
                                axis=1)
        out["labels"] = labels
    if frontend:
        for name, (shape, dtype) in frontend.items():
            out[name] = rng.standard_normal(
                (cfg.global_batch,) + tuple(shape)).astype(dtype)
    return out


def stream(cfg: DataConfig, start_step: int = 0, **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_for_step(cfg, step, **kw)
        step += 1
