"""Access-pattern workload generators for the plane benchmarks — the
analogues of the paper's application suite (Table 1).

Each generator yields batches of object ids with a characteristic pattern:

  * ``zipf_churn``   — MCD-CL: skewed with churn (hot set drifts over time)
  * ``uniform``      — MCD-U: uniform random, no hot set
  * ``two_phase``    — Metis PVC/WC: random-insert Map phase, then
                       sequential-scan Reduce phase (with optional skew runs)
  * ``graph_iter``   — GPR/ATC: random build, then repeated near-identical
                       iteration orders with a drifting update fraction
  * ``scan``         — DF Copy: pure sequential
  * ``grouped``      — WS: requests touch small co-accessed groups (32 keys)
"""
from __future__ import annotations

import numpy as np


def zipf_ranks(rng, n_objs, size, alpha=1.05):
    r = rng.zipf(alpha, size=size)
    return np.minimum(r - 1, n_objs - 1).astype(np.int32)


def zipf_churn(n_objs: int, batch: int, steps: int, *, alpha=1.05,
               churn_every=50, seed=0):
    """Skewed accesses whose identity mapping rotates (hot set drifts)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_objs)
    for t in range(steps):
        if t and t % churn_every == 0:
            # drift: re-map 10% of the id space
            k = n_objs // 10
            idx = rng.choice(n_objs, size=k, replace=False)
            perm[idx] = perm[np.roll(idx, 1)]
        yield perm[zipf_ranks(rng, n_objs, batch, alpha)].astype(np.int32)


def uniform(n_objs: int, batch: int, steps: int, *, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        yield rng.integers(0, n_objs, size=batch).astype(np.int32)


def two_phase(n_objs: int, batch: int, steps: int, *, skew_runs=True, seed=0):
    """Map phase (first half): random inserts, with occasional sequential
    runs when the data is skewed (paper Fig 1a).  Reduce phase (second
    half): sequential scan."""
    rng = np.random.default_rng(seed)
    half = steps // 2
    pos = 0
    for t in range(steps):
        if t < half:
            ids = rng.integers(0, n_objs, size=batch)
            if skew_runs and rng.random() < 0.25:
                start = rng.integers(0, max(n_objs - batch, 1))
                ids = np.arange(start, start + batch) % n_objs
            yield ids.astype(np.int32)
        else:
            ids = (pos + np.arange(batch)) % n_objs
            pos = (pos + batch) % n_objs
            yield ids.astype(np.int32)


def graph_iter(n_objs: int, batch: int, steps: int, *, build_frac=0.3,
               update_frac=0.05, seed=0):
    """Evolving-graph analytics: random build phase, then iterations that
    reuse a fixed traversal order, perturbed by graph updates."""
    rng = np.random.default_rng(seed)
    build = int(steps * build_frac)
    order = rng.permutation(n_objs)
    pos = 0
    for t in range(steps):
        if t < build:
            yield rng.integers(0, n_objs, size=batch).astype(np.int32)
        else:
            ids = order[(pos + np.arange(batch)) % n_objs].copy()
            n_upd = int(batch * update_frac)
            if n_upd:
                ids[:n_upd] = rng.integers(0, n_objs, size=n_upd)
            pos = (pos + batch) % n_objs
            yield ids.astype(np.int32)


def scan(n_objs: int, batch: int, steps: int, *, seed=0):
    pos = 0
    for _ in range(steps):
        yield ((pos + np.arange(batch)) % n_objs).astype(np.int32)
        pos = (pos + batch) % n_objs


def grouped(n_objs: int, batch: int, steps: int, *, group=32, alpha=1.05,
            seed=0):
    """WS-style: each request reads a zipf-chosen group of ``group``
    consecutive keys (keys co-accessed within a request)."""
    rng = np.random.default_rng(seed)
    n_groups = max(n_objs // group, 1)
    per = max(batch // group, 1)
    for _ in range(steps):
        g = zipf_ranks(rng, n_groups, per, alpha)
        ids = (g[:, None] * group + np.arange(group)[None, :]).reshape(-1)
        yield ids[:batch].astype(np.int32)


WORKLOADS = {
    "mcd_cl": zipf_churn,
    "mcd_u": uniform,
    "metis": two_phase,
    "graph": graph_iter,
    "df_scan": scan,
    "ws": grouped,
}
