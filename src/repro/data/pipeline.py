"""Host-side input pipeline: background prefetch of deterministic batches.

A producer thread builds batches ahead of the training loop (overlapping
host data generation with device compute) with a bounded queue; the
consumer draws the batch for each global step.  Restart-safe: the stream
is step-indexed, so a resumed job re-primes from its restored step.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


class Prefetcher:
    """Runs ``batch_fn(step)`` on a background thread, ``depth`` ahead."""

    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self.batch_fn = batch_fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._next
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, expect_step: Optional[int] = None) -> dict:
        step, batch = self._q.get()
        if expect_step is not None and step != expect_step:
            # restart / seek: rebuild deterministically (rare path)
            return self.batch_fn(expect_step)
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
