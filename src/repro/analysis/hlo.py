"""Compiled-HLO introspection: collective operand bytes with while-body
trip-count correction.

XLA's ``cost_analysis`` (and a naive text scan) counts a while-loop body
once, but scan-over-layers executes it ``L`` times.  We recover trip counts
from the loop *condition* computations (scan bounds lower to a
``constant(L)`` compared against the induction variable) and propagate
multipliers through the call graph.
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
               "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_computations(text: str) -> dict:
    """Split HLO text into {computation_name: [lines]}."""
    comps = {}
    cur = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|=)", line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps.setdefault(cur, []).append(line)
    return comps


def _entry_name(text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else next(iter(parse_computations(text)), "")


def computation_multipliers(text: str) -> dict:
    """Trip-count multiplier per computation (ENTRY = 1; while bodies get
    their loop bound; nested loops multiply)."""
    comps = parse_computations(text)
    entry = _entry_name(text)

    # trip count heuristic: max integer constant in the loop condition
    def trip_of(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                best = max(best, int(c))
        return min(best, 1_000_000)

    # call edges: while(cond=..., body=...), call/fusion to_apply etc.
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        m = mult[name]
        for line in comps.get(name, []):
            wm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", line)
            if wm:
                cond, body = wm.groups()
                trips = trip_of(cond)
                for callee, factor in ((body, trips), (cond, trips)):
                    mult[callee] = max(mult[callee], m * factor)
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)
            for cm in re.finditer(
                    r"(?:to_apply|calls)=%?([\w.\-]+)", line):
                callee = cm.group(1)
                mult[callee] = max(mult[callee], m)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return dict(mult)


def collective_summary(text: str) -> dict:
    """Per-device collective traffic from the compiled module.

    Returns counts and byte totals per collective kind, both *static*
    (each op once) and *corrected* (x while trip counts), using ring-cost
    models: AR 2(n-1)/n, AG/RS/A2A (n-1)/n-ish, CP 1x."""
    comps = parse_computations(text)
    mult = computation_multipliers(text)
    out = {k: {"count": 0, "bytes_static": 0.0, "bytes_corrected": 0.0,
               "wire_bytes_corrected": 0.0} for k in COLLECTIVES}

    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            stripped = line.strip()
            for kind in COLLECTIVES:
                # match: %x = <shape> kind( ... (also kind-start/done pairs)
                if re.search(rf"\s{kind}(?:-start)?\(", stripped):
                    lhs = stripped.split(f" {kind}", 1)[0]
                    size = _shape_bytes(lhs)
                    n = _group_size(stripped, 1)
                    if kind == "all-reduce":
                        wire = 2.0 * size * (n - 1) / max(n, 1)
                    elif kind == "all-gather":
                        wire = size * (n - 1) / max(n, 1)
                    elif kind == "reduce-scatter":
                        wire = size * (n - 1)
                    elif kind == "all-to-all":
                        wire = size * (n - 1) / max(n, 1)
                    else:
                        wire = float(size)
                    out[kind]["count"] += 1
                    out[kind]["bytes_static"] += size
                    out[kind]["bytes_corrected"] += size * m
                    out[kind]["wire_bytes_corrected"] += wire * m
                    break

    out["total_wire_bytes_corrected"] = sum(
        v["wire_bytes_corrected"] for k, v in out.items()
        if isinstance(v, dict))
    out["total_bytes_corrected"] = sum(
        v["bytes_corrected"] for k, v in out.items() if isinstance(v, dict))
    return out
