"""Analytic FLOPs / HBM-bytes / collective model per (arch x shape x mesh).

This is the trip-count-exact companion to the HLO-derived numbers (XLA's
cost_analysis counts scan bodies once — verified experimentally; see
EXPERIMENTS.md §Dry-run).  All quantities are PER DEVICE per step.

Conventions: bf16 activations/params (2 B), f32 logits/optimizer.
Causal attention scores+AV ~ 2*B*H*S^2*Dh per layer forward (the 0.5
causal factor applied to the 4*... dense count); backward = 2x forward.
"""
from __future__ import annotations

import dataclasses

from repro import configs as cfgs
from repro.models.lm import pad_vocab

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link (per direction)

PAGE_TOKENS = 64
SPARSE_TOPK = 64


def override_layers(cfg, L: int):
    fam = cfg.family
    if fam == "ssm":
        return dataclasses.replace(cfg, n_layers=2 * L)
    if fam == "encdec":
        return dataclasses.replace(cfg, enc_layers=L, dec_layers=L,
                                   n_layers=2 * L)
    if fam == "hybrid":
        return cfg  # fixed 6-group structure; probe unsupported
    return dataclasses.replace(cfg, n_layers=L)


def layer_params(cfg) -> dict:
    """Per-layer parameter counts by component (one 'group' for ssm/hybrid
    counts its full contents / group count)."""
    d, ff = cfg.d_model, cfg.d_ff
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * H * hd + 2 * d * KVH * hd + H * hd * d
    out = {"attn": attn}
    if cfg.moe_experts:
        out["moe"] = cfg.moe_experts * 3 * d * ff + d * cfg.moe_experts
        out["moe_active"] = cfg.moe_topk * 3 * d * ff + d * cfg.moe_experts
    elif ff:
        out["mlp"] = 3 * d * ff
    if cfg.family == "ssm":   # mLSTM + sLSTM pair, per 2-layer group
        di = 2 * d
        mlstm = d * 2 * di + 3 * di * di + di * 2 * cfg.n_heads + di * d
        dff = int(4 / 3 * d)
        slstm = 4 * d * d + cfg.n_heads * (d // cfg.n_heads) ** 2 * 4 \
            + 2 * d * dff + dff * d
        out = {"mlstm": mlstm, "slstm": slstm}
    if cfg.family == "hybrid":
        di = 2 * d
        Hm = di // 64
        mamba = d * (2 * di + 2 * cfg.ssm_state + Hm) + di * d
        out = {"mamba": mamba, "shared_attn": attn + 3 * d * ff}
    return out


def total_params(cfg) -> dict:
    vp = pad_vocab(cfg.vocab)
    lp = layer_params(cfg)
    embed = vp * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    fam = cfg.family
    if fam == "ssm":
        body = (cfg.n_layers // 2) * (lp["mlstm"] + lp["slstm"])
        active = body
    elif fam == "hybrid":
        body = 32 * lp["mamba"] + lp["shared_attn"]
        active = 32 * lp["mamba"] + 6 * lp["shared_attn"]
    elif fam == "encdec":
        enc = cfg.enc_layers * (lp["attn"] + 3 * cfg.d_model * cfg.d_ff)
        dec = cfg.dec_layers * (2 * lp["attn"] + 3 * cfg.d_model * cfg.d_ff)
        body, active = enc + dec, enc + dec
    elif cfg.moe_experts:
        body = cfg.n_layers * (lp["attn"] + lp["moe"])
        active = cfg.n_layers * (lp["attn"] + lp["moe_active"])
    else:
        body = cfg.n_layers * (lp["attn"] + lp.get("mlp", 0))
        active = body
    return {"total": body + embed, "active": active + embed, "body": body,
            "embed": embed}


def _attn_flops_fwd(cfg, B, S, window=0):
    eff = min(window, S) if window else S
    return 2 * B * cfg.n_heads * S * eff * cfg.hd  # causal 0.5 applied


def cell_model(arch: str, shape_name: str, mesh_kind: str) -> dict:
    cfg = cfgs.get_config(arch)
    shape = cfgs.SHAPES[shape_name]
    chips = 512 if mesh_kind == "multi" else 256
    dp = 32 if mesh_kind == "multi" else 16
    tp = 16
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    p = total_params(cfg)
    vp = pad_vocab(cfg.vocab)
    d = cfg.d_model

    rec = {"params_total": p["total"], "params_active": p["active"],
           "chips": chips}

    if shape.kind == "train":
        mat = 6 * p["active"] * tokens            # fwd 2ND + bwd 4ND
        attn = 3 * _attn_flops_fwd(cfg, B, S, cfg.sliding_window) \
            * (cfg.n_layers if cfg.family not in ("ssm", "hybrid") else 6)
        flops = (mat + attn) / chips
        # HBM: params+grads+opt traffic + activation r/w with full remat
        # (~2 fwd passes + 1 bwd): ~14 bytes/token/d per layer-ish
        layers = cfg.n_layers
        act_bytes = 14 * tokens * d * layers * 2 / chips
        wt_bytes = (p["total"] * 2 * 3 + p["total"] * 4 * 2) / chips
        hbm = act_bytes + wt_bytes
        # collectives: FSDP all-gather (fwd+bwd) + reduce-scatter grads over
        # dp; TP all-reduce of activations 4x/layer (fwd+bwd) over tp
        fsdp = 3 * p["body"] * 2 * (dp - 1) / dp / tp
        tp_act = 4 * 2 * layers * tokens * d * 2 * (tp - 1) / tp / chips
        logits_ar = tokens * vp * 4 / chips * 0  # logits stay sharded
        moe_a2a = 0.0
        if cfg.moe_experts:
            moe_a2a = 3 * 2 * layers * tokens * cfg.moe_topk * d * 2 / chips
        coll = fsdp + tp_act + logits_ar + moe_a2a
    elif shape.kind == "prefill":
        mat = 2 * p["active"] * tokens
        attn = _attn_flops_fwd(cfg, B, S, cfg.sliding_window) \
            * (cfg.n_layers if cfg.family not in ("ssm", "hybrid") else 6)
        flops = (mat + attn) / chips
        hbm = (p["total"] * 2 + 6 * tokens * d * cfg.n_layers * 2) / chips
        coll = (2 * 2 * cfg.n_layers * tokens * d * 2 * (tp - 1) / tp
                / chips)
    else:  # decode: one token per sequence
        mat = 2 * p["active"] * B
        if shape.kind == "decode_long" and not cfg.sliding_window \
                and cfg.family != "ssm":
            kv_tokens = SPARSE_TOKENS_READ = SPARSE_TOPK * PAGE_TOKENS
        elif cfg.sliding_window and shape.kind == "decode_long":
            kv_tokens = cfg.sliding_window
        else:
            kv_tokens = S
        layers = {"ssm": 0, "hybrid": 6}.get(cfg.family, cfg.n_layers)
        attn = 4 * B * cfg.n_heads * kv_tokens * cfg.hd * layers
        flops = (mat + attn) / chips
        kv_bytes = (2 * B * kv_tokens * cfg.n_kv_heads * cfg.hd * 2
                    * max(layers, 1))
        hbm = (p["active"] * 2 + kv_bytes) / chips
        # TP all-reduce per layer of B*d activations (attn out + mlp out)
        coll = 2 * max(layers, 1) * B * d * 2 * (tp - 1) / tp / chips

    rec.update({
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": hbm,
        "collective_bytes_per_chip": coll,
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": hbm / HBM_BW,
        "t_collective_s": coll / ICI_BW,
        "model_flops_global": flops * chips,
    })
    terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
             "collective": rec["t_collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec
