"""Fault-tolerant sharded checkpointing with elastic restore.

Design (tensorstore-free, works on any shared filesystem):
  * each pytree leaf -> one ``.npy`` file under ``step_<N>.tmp/``
  * ``manifest.json`` records the tree structure, dtypes, shapes and step
  * the tmp dir is atomically renamed to ``step_<N>/`` (a crash mid-write
    never corrupts the latest checkpoint)
  * ``latest()`` resolves the newest complete step
  * restore takes an OPTIONAL mesh + spec tree: arrays are re-sharded on
    load, so a job may restart on a different topology (elastic scaling)
  * ``AsyncCheckpointer`` runs saves on a background thread and the
    trainer's failure hook flushes a final emergency save
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path) or "leaf"
        name = name.replace("/", "_").replace("'", "")
        out.append((name, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None):
    """Synchronous atomic sharded save."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:05d}_{name[:80]}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomic publish
    return final


def latest(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any, *,
            mesh=None, spec_tree=None) -> Any:
    """Load a checkpoint into ``template``'s tree structure.

    With ``mesh``+``spec_tree`` the arrays are placed with the given
    shardings — a restart may use a different mesh than the writer
    (elastic scaling)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    _, treedef = _flatten_with_paths(template)
    arrays = [np.load(os.path.join(path, rec["file"]))
              for rec in manifest["leaves"]]
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if mesh is not None and spec_tree is not None:
        from repro.launch import mesh as mesh_lib
        shardings = mesh_lib.sharding_tree(mesh, spec_tree)
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest.get("extra", {})


def prune(ckpt_dir: str, keep: int = 3):
    """Drop all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (one in flight; newer requests
    supersede queued ones)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False):
        # snapshot to host BEFORE returning control (donation safety)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            save(self.dir, step, host_tree, extra=extra)
            prune(self.dir, self.keep)

        with self._lock:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        if block:
            self.wait()

    def wait(self):
        t = self._thread
        if t is not None and t.is_alive():
            t.join()
