"""Optimizers: AdamW and Adafactor (factored second moments, for
trillion-parameter configs), with global-norm clipping and schedules.

Implemented natively (no optax dependency) as pure pytree transforms:
``init(params) -> state``; ``update(grads, state, params, step) ->
(new_params, new_state)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable = cosine_schedule(3e-4, 100, 10000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(self, grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, self.clip)
        t = (step + 1).astype(jnp.float32)
        lr = self.lr(step)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = self.b1 * mu + (1 - self.b1) * g
            nu = self.b2 * nu + (1 - self.b2) * g * g
            step_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            newp = (p.astype(jnp.float32)
                    - lr * (step_ + self.weight_decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), mu, nu

        flat_g, tdef = jax.tree.flatten(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        flat_nu = tdef.flatten_up_to(state["nu"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, n, p) for g, m, n, p
               in zip(flat_g, flat_mu, flat_nu, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                     "nu": tdef.unflatten([o[2] for o in out])}
        return new_p, new_state, gnorm


# --------------------------------------------------------------------------
# Adafactor (factored 2nd moments — the trillion-parameter option)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable = cosine_schedule(1e-3, 100, 10000)
    decay: float = 0.8      # beta2 exponent: 1 - t^-decay
    eps: float = 1e-30
    clip: float = 1.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params):
        def per(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(per, params)}

    def update(self, grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, self.clip)
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)
        lr = self.lr(step)

        def upd(g, fac, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if self._factored(p.shape):
                vr = beta2 * fac["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * fac["vc"] + (1 - beta2) * g2.mean(-2)
                rms = (vr[..., :, None] * vc[..., None, :]
                       / jnp.maximum(vr.mean(-1)[..., None, None], self.eps))
                u = g * jax.lax.rsqrt(jnp.maximum(rms, self.eps))
                newfac = {"vr": vr, "vc": vc}
            else:
                v = beta2 * fac["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, self.eps))
                newfac = {"v": v}
            # update clipping (Adafactor's d=1.0 RMS rule)
            u = u / jnp.maximum(1.0, jnp.sqrt(jnp.mean(u * u)))
            newp = p.astype(jnp.float32) - lr * u
            return newp.astype(p.dtype), newfac

        flat_g, tdef = jax.tree.flatten(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                {"f": tdef.unflatten([o[1] for o in out])}, gnorm)


def get_optimizer(name: str, **kw):
    return {"adamw": AdamW, "adafactor": Adafactor}[name](**kw)
