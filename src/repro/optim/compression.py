"""Gradient compression for cross-pod data parallelism.

Int8 error-feedback quantization: before the (slow, cross-pod) all-reduce,
gradients are quantized to int8 with a per-tensor scale; the quantization
residual is fed back into the next step's gradient (error feedback keeps
SGD convergence).  Cross-pod traffic drops 4x (f32) / 2x (bf16).

Used by the trainer when the mesh has a "pod" axis; the dry-run cost model
credits the reduced wire bytes (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: jnp.ndarray


def init_ef(params):
    return jax.tree.map(
        lambda p: EFState(jnp.zeros(p.shape, jnp.float32)), params)


def quantize(g: jnp.ndarray, residual: jnp.ndarray):
    """Returns (q int8, scale, new_residual)."""
    g = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def compress_tree(grads, ef_state):
    """Quantize every leaf with error feedback; returns (q_tree, scales,
    new_ef)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    qs, scales, res = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, r = quantize(g, e.residual)
        qs.append(q); scales.append(s); res.append(EFState(r))
    return (tdef.unflatten(qs), tdef.unflatten(scales),
            tdef.unflatten(res))


def decompress_tree(q_tree, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales)
