from .optimizers import AdamW, Adafactor, get_optimizer, clip_by_global_norm, \
    global_norm, cosine_schedule
from .accumulation import accumulated_value_and_grad
from . import compression, schedules

__all__ = ["AdamW", "Adafactor", "get_optimizer", "clip_by_global_norm",
           "global_norm", "cosine_schedule", "accumulated_value_and_grad",
           "compression", "schedules"]
