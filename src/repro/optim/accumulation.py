"""Gradient accumulation (microbatching): train with a global batch larger
than fits activation memory by scanning micro-steps and averaging grads.
Works with any loss fn; the batch's leading dim is split into
``num_micro`` chunks inside the jitted step (single optimizer update)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def accumulated_value_and_grad(loss_fn, num_micro: int):
    """Returns fn(params, batch) -> (mean_loss, grads) evaluating the loss
    in ``num_micro`` sequential microbatches."""
    if num_micro <= 1:
        return jax.value_and_grad(loss_fn)

    vg = jax.value_and_grad(loss_fn)

    def split(batch):
        def per(x):
            b = x.shape[0]
            assert b % num_micro == 0, (b, num_micro)
            return x.reshape((num_micro, b // num_micro) + x.shape[1:])
        return jax.tree.map(per, batch)

    def fn(params, batch):
        micro = split(batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            l, g = vg(params, mb)
            grad_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), grad_acc, g)
            return (loss_acc + l, grad_acc), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = lax.scan(body, (jnp.zeros(()), zero), micro)
        inv = 1.0 / num_micro
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return fn
