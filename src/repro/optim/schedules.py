"""LR schedules (cosine with warmup re-exported + linear/const)."""
from .optimizers import cosine_schedule  # noqa: F401
import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(base_lr: float, warmup: int):
    def lr(step):
        s = step.astype(jnp.float32)
        return base_lr * jnp.minimum(1.0, s / max(warmup, 1))
    return lr
