"""codeqwen1.5-7b — qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf].

32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440 vocab=92416.
Pure full attention: long_500k skipped (DESIGN.md)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=13440, vocab=92416, rope_theta=1e6)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512)
