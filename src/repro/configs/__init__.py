"""Architecture configs + shape registry.

Each assigned architecture has a module ``repro.configs.<id>`` (dash ->
underscore) exporting ``CONFIG`` (exact assigned hyperparameters) and
``SMOKE`` (a reduced same-family config for CPU smoke tests).

``get_config(name)`` / ``get_smoke(name)`` resolve by arch id;
``SHAPES`` maps shape ids to (seq_len, global_batch, kind).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity: float = 1.25
    # --- attention ----------------------------------------------------------
    sliding_window: int = 0        # >0: SWA (mixtral)
    rope_theta: float = 1e4
    # --- recurrent ----------------------------------------------------------
    ssm_state: int = 0
    block_pattern: Tuple[str, ...] = ()   # per-scan-group block sequence
    shared_attn_period: int = 0    # zamba: shared attn every N blocks
    # --- encoder-decoder ------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    # --- frontend stubs ---------------------------------------------------
    frontend: str = "none"         # none | audio | vision
    frontend_seq: int = 0          # frames / patches provided by input_specs
    frontend_dim: int = 0          # stub embedding width
    # --- numerics / features ----------------------------------------------
    dtype: Any = jnp.bfloat16
    remat: bool = True
    tie_embeddings: bool = False
    subquadratic: bool = False     # may run long_500k
    atlas_kv: bool = True          # KV cache managed by the hybrid plane
    atlas_experts: bool = False    # expert weights managed by the plane
    # decode sparse-attention (Atlas runtime path showcase)
    sparse_topk_pages: int = 0     # >0: top-k paged sparse decode attention

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode | decode_long


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode_long"),
}

ARCHS = [
    "xlstm-350m", "codeqwen1.5-7b", "granite-20b", "llama3-8b", "yi-9b",
    "mixtral-8x7b", "kimi-k2-1t-a32b", "zamba2-1.2b", "seamless-m4t-medium",
    "paligemma-3b",
]

# pure full-attention archs skip long_500k (see DESIGN.md §Arch-applicability)
LONG_SKIP = {"codeqwen1.5-7b", "granite-20b", "yi-9b", "seamless-m4t-medium",
             "paligemma-3b"}


def _module(name: str):
    return importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged."""
    out = []
    for a in ARCHS:
        for sh in SHAPES.values():
            skipped = sh.name == "long_500k" and a in LONG_SKIP
            if skipped and not include_skipped:
                continue
            out.append((a, sh.name, skipped))
    return out
