"""mixtral-8x7b — 8 experts top-2, SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
sliding window 4096.  SWA is sub-quadratic: long_500k runs with the
ring-buffer window KV plane.  Experts are TP-sharded (8 % 16 != 0 ->
expert-replicated tensor parallelism; see DESIGN.md)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
    moe_experts=8, moe_topk=2, sliding_window=4096, subquadratic=True)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=512, moe_experts=4, sliding_window=32)
