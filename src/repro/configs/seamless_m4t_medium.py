"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596; hf].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 (padded to 256256 for
TP divisibility).  Audio frontend is a STUB: input_specs() provides
precomputed frame embeddings [B, S/4, d_model].  Enc-dec full attention:
long_500k skipped."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    enc_layers=12, dec_layers=12, frontend="audio")

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, enc_layers=2, dec_layers=2)
