"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(per-expert) vocab=163840,
MoE 384e top-8.  Expert parallelism (384 % 16 == 0) x FSDP; Adafactor
optimizer (AdamW state would not fit 256 chips — see EXPERIMENTS.md).
Serving uses the Atlas expert plane (hot experts in HBM, cold in the far
tier)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
    moe_experts=384, moe_topk=8, atlas_experts=True)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=32, vocab=512, moe_experts=8, moe_topk=2)
