"""paligemma-3b — SigLIP + gemma [arXiv:2407.07726; hf].

18L d_model=2048 8H (MQA kv=1, head_dim=256) d_ff=16384 vocab=257216.
Vision frontend is a STUB: input_specs() provides 256 precomputed patch
embeddings (SigLIP width 1152) projected into the LM.  Full attention:
long_500k skipped."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, d_ff=16384, vocab=257216, head_dim=256,
    frontend="vision", frontend_seq=256, frontend_dim=1152,
    tie_embeddings=True)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                      d_ff=128, vocab=512, head_dim=16, frontend_seq=16,
                      frontend_dim=32)
