"""zamba2-1.2b — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Structure: 6 groups of (5 mamba2 + 1 shared-attention application) + 2
tail mamba2 = 38 layer applications; the attention block's weights are
shared across applications (see DESIGN.md for deviations).  Hybrid ->
long_500k runs; the shared-attn KV uses the Atlas sparse plane."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, ssm_state=64,
    subquadratic=True, sparse_topk_pages=64)

SMOKE = CONFIG.scaled(n_layers=38, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, ssm_state=8)
