"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (block-internal projections) vocab=50304.
Alternating mLSTM/sLSTM (12 groups of 2).  Recurrent state is O(d_model):
the KV plane is inapplicable (DESIGN.md §Arch-applicability); the plane
manages only far-resident embedding tables in serving.  long_500k runs
natively (O(1) state)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, subquadratic=True, atlas_kv=False)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, vocab=512)
