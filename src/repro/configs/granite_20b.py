"""granite-20b — llama-arch, code [arXiv:2405.04324; hf].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
Pure full attention: long_500k skipped."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152)

SMOKE = CONFIG.scaled(n_layers=2, d_model=96, n_heads=6, n_kv_heads=1,
                      d_ff=192, vocab=512)
