"""llama3-8b — GQA, 128k vocab [arXiv:2407.21783; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
The paper-technique showcase arch: long_500k runs WITH the Atlas hybrid
KV plane (top-k paged sparse decode attention -> sub-quadratic)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, rope_theta=5e5,
    subquadratic=True, sparse_topk_pages=64)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=512)
