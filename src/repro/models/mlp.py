"""Feed-forward layers: SwiGLU MLP and sort-based dropping MoE.

The MoE uses the MaxText-style *dropping* formulation: top-k routing, token
sort by expert, capacity-bounded scatter into per-expert buffers, batched
expert matmuls, weighted combine.  Under GSPMD the expert dimension is
sharded over the model axis when divisible (expert parallelism; kimi-k2),
otherwise experts are replicated and their inner dimension is
tensor-parallel (mixtral)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import BATCH, DP, TP, ParamDef, dense


def mlp_defs(d_model: int, d_ff: int, dtype) -> dict:
    return {
        "wi": ParamDef((d_model, d_ff), (DP, TP), dtype=dtype),
        "wg": ParamDef((d_model, d_ff), (DP, TP), dtype=dtype),
        "wo": ParamDef((d_ff, d_model), (TP, DP), dtype=dtype),
    }


def mlp(params, x):
    h = jax.nn.silu(dense(x, params["wg"]).astype(jnp.float32)).astype(x.dtype)
    return dense(h * dense(x, params["wi"]), params["wo"])


def moe_defs(d_model: int, d_ff: int, n_experts: int, shard_experts: bool,
             dtype) -> dict:
    # EP when the expert count divides the model axis; else TP inside experts
    e_axis, f_axis = (TP, None) if shard_experts else (None, TP)
    return {
        "router": ParamDef((d_model, n_experts), (DP, None), dtype=jnp.float32),
        "wi": ParamDef((n_experts, d_model, d_ff), (e_axis, DP, f_axis), dtype=dtype),
        "wg": ParamDef((n_experts, d_model, d_ff), (e_axis, DP, f_axis), dtype=dtype),
        "wo": ParamDef((n_experts, d_ff, d_model), (e_axis, f_axis, DP), dtype=dtype),
    }


def moe(params, x, *, n_experts: int, topk: int, capacity_factor: float = 1.25,
        n_groups: int = 0):
    """x: [B, S, d] -> [B, S, d] plus aux load-balancing loss.

    *Group-local* static-shaped dropping MoE: tokens are partitioned into
    ``n_groups`` groups aligned with the data shards; routing, ranking and
    the capacity-bounded dispatch scatter are group-local (no cross-shard
    gathers), so the only inter-device movement is the inherent
    expert-parallel all-to-all of the dispatched [G, E, Cg, d] buffers —
    GSPMD lowers the (G:dp, E:tp) -> expert-major resharding to exactly
    that (§Perf iteration B1: 21 TB -> inherent a2a for kimi-k2).

      1. router logits -> top-k (weights renormalized)
      2. per-(group, expert) rank via stable sort + segment starts
      3. scatter into [G, E, Cg, d] dispatch buffers (losers dropped)
      4. batched expert SwiGLU against the (E:tp)-sharded weights
      5. weighted combine back to token order (reverse exchange)
    """
    from .common import shard
    B, S, d = x.shape
    T = B * S
    E, K = n_experts, topk
    G = n_groups or math.gcd(B, 16) or 1
    Tg = T // G
    Cg = max(int(Tg * K * capacity_factor / E), 1)
    Cg = -(-Cg // 4) * 4

    xg = x.reshape(G, Tg, d)
    xg = shard(xg, (BATCH, None, None))

    logits = jnp.einsum("gtd,de->gte", xg,
                        params["router"].astype(x.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [G, Tg, E]
    gate, expert = jax.lax.top_k(probs, K)                      # [G, Tg, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style load balancing, global)
    me = probs.mean(axis=(0, 1))                                # [E]
    ce = jnp.zeros((E,), jnp.float32).at[expert.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    # --- per-group rank within expert (sort-based, vmapped) -------------
    def group_rank(flat_expert):                                # [Tg*K]
        sort_idx = jnp.argsort(flat_expert)                     # stable
        sorted_expert = flat_expert[sort_idx]
        pos = jnp.arange(Tg * K, dtype=jnp.int32)
        seg_start = jnp.full((E,), Tg * K, jnp.int32).at[sorted_expert].min(
            pos)
        rank_sorted = pos - seg_start[sorted_expert]
        return jnp.zeros((Tg * K,), jnp.int32).at[sort_idx].set(rank_sorted)

    flat_expert = expert.reshape(G, Tg * K)
    rank = jax.vmap(group_rank)(flat_expert)                    # [G, Tg*K]

    keep = rank < Cg
    dst = jnp.where(keep, flat_expert * Cg + rank, E * Cg)      # overflow

    # --- group-local dispatch -------------------------------------------
    src_tok = jnp.repeat(jnp.arange(Tg), K)

    def group_scatter(xt_g, dst_g):
        buf = jnp.zeros((E * Cg + 1, d), x.dtype)
        return buf.at[dst_g].set(xt_g[src_tok])[:-1]

    xe = jax.vmap(group_scatter)(xg, dst).reshape(G, E, Cg, d)
    # dispatch buffers stay group-local (full E per data shard); the expert
    # einsum against the (E:tp)-sharded weights is then block-local and the
    # E-dim reshard happens on the (much smaller) expert outputs
    xe = shard(xe, (BATCH, None, None, None))

    # --- expert computation (batched SwiGLU) ----------------------------
    g_ = jnp.einsum("gecd,edf->gecf", xe, params["wg"],
                    preferred_element_type=x.dtype)
    i_ = jnp.einsum("gecd,edf->gecf", xe, params["wi"],
                    preferred_element_type=x.dtype)
    h = (jax.nn.silu(g_.astype(jnp.float32)) * i_.astype(jnp.float32)
         ).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"],
                    preferred_element_type=x.dtype)
    ye = shard(ye, (BATCH, None, None, None))   # reverse exchange to dp

    # --- combine ---------------------------------------------------------
    def group_gather(ye_g, dst_g):
        flat = jnp.concatenate([ye_g.reshape(E * Cg, d),
                                jnp.zeros((1, d), ye_g.dtype)], axis=0)
        return flat[dst_g]

    yt = jax.vmap(group_gather)(ye, dst).reshape(G, Tg, K, d)
    w = jnp.where(keep.reshape(G, Tg, K), gate, 0.0).astype(jnp.float32)
    out = jnp.einsum("gtkd,gtk->gtd", yt.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype), aux
