"""Lightweight functional module system + common layers.

Parameters are plain nested dicts of arrays.  A model is defined as a
pytree of :class:`ParamDef` (shape + initializer + logical partition spec);
``init_params`` materializes arrays, ``pspecs`` extracts the sharding tree.

Logical sharding axes used in specs (resolved against the mesh by
``repro.launch.mesh.resolve``):
  * ``"dp"`` — data/FSDP axis; maps to ``("pod", "data")`` on the multi-pod
    mesh and ``("data",)`` on the single-pod mesh.
  * ``"tp"`` — tensor-parallel axis; maps to ``"model"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

DP = "dp"
TP = "tp"
BATCH = "batch"


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    spec: tuple                      # logical partition spec (strings / None)
    init: str = "normal"             # normal | zeros | ones | embed
    scale: float = 1.0
    dtype: Any = jnp.float32

    def initialize(self, key) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            fan_in = self.shape[0] if len(self.shape) == 1 else self.shape[-2]
            std = self.scale / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape, jnp.float32) * std
                    ).astype(self.dtype)
        if self.init == "embed":
            return (jax.random.normal(key, self.shape, jnp.float32)
                    * self.scale).astype(self.dtype)
        raise ValueError(self.init)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [d.initialize(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def shapes(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def)


def pspecs(defs):
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def stack_layers(defs, n: int):
    """Prefix every ParamDef with a layer axis (for scan-over-layers)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + tuple(d.spec),
                           d.init, d.scale, d.dtype),
        defs, is_leaf=is_def)


# --------------------------------------------------------------------------
# layers
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4):
    """Rotary embedding.  x: [..., S, H, Dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq      # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                           # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rot.astype(x.dtype)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x [..., d_in] @ w [d_in, d_out].

    The dot's output dtype matches the input: on TPU the MXU accumulates
    in f32 internally either way, but a bf16 output means the *cross-shard*
    partial-sum all-reduce GSPMD inserts for tensor parallelism moves bf16,
    halving TP collective bytes (§Perf iteration 2)."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype).astype(x.dtype)


def shard(x: jnp.ndarray, spec: tuple):
    """Logical-axis sharding constraint (no-op outside a mesh context)."""
    from repro.launch import mesh as mesh_lib
    return mesh_lib.constrain(x, spec)
