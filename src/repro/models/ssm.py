"""Recurrent sequence mixers: a shared chunked gated linear recurrence
(Mamba2 SSD and xLSTM's mLSTM are both instances), plus the sequential
sLSTM cell.

The recurrence is  S_t = a_t * S_{t-1} + k_t v_t^T,   y_t = q_t @ S_t
with per-(step, head) scalar decay ``a_t = exp(log_a_t)``.  Training uses a
chunkwise-parallel form (intra-chunk attention-like matmuls + inter-chunk
state passing); decode is the O(1) recurrent update.

Deviations from the papers (documented in DESIGN.md): the mLSTM input gate
uses a capped exponential + normalizer instead of the running-max
stabilizer (numerically safe, same structure); Zamba2's shared block
consumes the hidden state only (no embedding concat / LoRA adapters).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .common import DP, TP, ParamDef, dense, rms_norm


# --------------------------------------------------------------------------
# chunked gated linear recurrence
# --------------------------------------------------------------------------

def chunked_linear_rnn(q, k, v, log_a, s0=None, *, chunk: int = 128):
    """q,k: [B, S, H, dk]; v: [B, S, H, dv]; log_a: [B, S, H] (<= 0).
    Returns (y [B, S, H, dv], s_final [B, H, dk, dv])."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    while S % L:
        L //= 2
    n = S // L

    qc = q.reshape(B, n, L, H, dk).transpose(1, 0, 3, 2, 4)   # [n,B,H,L,dk]
    kc = k.reshape(B, n, L, H, dk).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n, L, H, dv).transpose(1, 0, 3, 2, 4)
    ac = log_a.reshape(B, n, L, H).transpose(1, 0, 3, 2)      # [n,B,H,L]

    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def chunk_step(s, inputs):
        qi, ki, vi, lai = inputs                               # [B,H,L,*]
        lai = lai.astype(jnp.float32)
        A = jnp.cumsum(lai, axis=-1)                           # [B,H,L]
        # intra-chunk: y_i += sum_{j<=i} exp(A_i - A_j) (q_i.k_j) v_j
        qf = qi.astype(jnp.float32)
        kf = ki.astype(jnp.float32)
        vf = vi.astype(jnp.float32)
        scores = jnp.einsum("bhid,bhjd->bhij", qf, kf)
        decay = A[..., :, None] - A[..., None, :]              # [B,H,L,L]
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal, jnp.exp(decay), 0.0)
        y = jnp.einsum("bhij,bhjd->bhid", scores * w, vf)
        # inter-chunk: y_i += exp(A_i) q_i @ s_in
        y += jnp.exp(A)[..., None] * jnp.einsum("bhid,bhdv->bhiv", qf, s)
        # state update: s_out = exp(A_L) s + sum_j exp(A_L - A_j) k_j v_j^T
        tail = jnp.exp(A[..., -1:] - A)                        # [B,H,L]
        s = jnp.exp(A[..., -1])[..., None, None] * s + jnp.einsum(
            "bhjd,bhjv->bhdv", kf * tail[..., None], vf)
        return s, y

    s_final, ys = lax.scan(chunk_step, s0, (qc, kc, vc, ac))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return y.astype(v.dtype), s_final


def linear_rnn_step(q, k, v, log_a, s):
    """Single-token recurrence.  q,k: [B, H, dk]; v: [B, H, dv];
    log_a: [B, H]; s: [B, H, dk, dv] -> (y [B, H, dv], s')."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    s = a * s + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                           v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), s)
    return y.astype(v.dtype), s


# --------------------------------------------------------------------------
# Mamba2 block (SSD)
# --------------------------------------------------------------------------

def mamba2_defs(d_model: int, ssm_state: int, dtype, *, expand: int = 2,
                head_dim: int = 64, conv_width: int = 4) -> dict:
    d_inner = expand * d_model
    H = d_inner // head_dim
    return {
        "norm": ParamDef((d_model,), (None,), "ones", dtype=dtype),
        "in_proj": ParamDef((d_model, 2 * d_inner + 2 * ssm_state + H),
                            (DP, TP), dtype=dtype),
        "conv": ParamDef((conv_width, d_inner + 2 * ssm_state), (None, TP),
                         "normal", dtype=dtype),
        "A_log": ParamDef((H,), (None,), "zeros", dtype=jnp.float32),
        "D": ParamDef((H,), (None,), "ones", dtype=jnp.float32),
        "dt_bias": ParamDef((H,), (None,), "zeros", dtype=jnp.float32),
        "out_norm": ParamDef((d_inner,), (None,), "ones", dtype=dtype),
        "out_proj": ParamDef((d_inner, d_model), (TP, DP), dtype=dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: [B, S, C]; w: [W, C].
    state: [B, W-1, C] carried inputs for decode; returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):]
    return y.astype(x.dtype), new_state


def mamba2_block(params, x, cfg, state=None, *, chunk: int = 128):
    """x: [B, S, d_model].  state: optional (conv_state, ssm_state) for
    decode continuation.  Returns (y, new_state)."""
    B, S, d = x.shape
    N = cfg.ssm_state
    d_inner = 2 * d
    head_dim = 64
    H = d_inner // head_dim

    h = rms_norm(x, params["norm"])
    proj = dense(h, params["in_proj"])
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    conv_state = None if state is None else state[0]
    xbc, new_conv = _causal_conv(xbc, params["conv"], conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs, Bc, Cc = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    A = -jnp.exp(params["A_log"])                                     # [H] < 0
    log_a = dt * A                                                    # [B,S,H]

    xh = xs.reshape(B, S, H, head_dim)
    v = xh * dt[..., None].astype(x.dtype)
    k = jnp.broadcast_to(Bc[:, :, None, :], (B, S, H, N))
    q = jnp.broadcast_to(Cc[:, :, None, :], (B, S, H, N))

    s0 = None if state is None else state[1]
    y, s_final = chunked_linear_rnn(q, k, v, log_a, s0, chunk=chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["out_norm"])
    return x + dense(y, params["out_proj"]), (new_conv, s_final)


# --------------------------------------------------------------------------
# xLSTM blocks
# --------------------------------------------------------------------------

def mlstm_defs(d_model: int, n_heads: int, dtype, *, expand: int = 2) -> dict:
    d_inner = expand * d_model
    return {
        "norm": ParamDef((d_model,), (None,), "ones", dtype=dtype),
        "up_proj": ParamDef((d_model, 2 * d_inner), (DP, TP), dtype=dtype),
        "wq": ParamDef((d_inner, d_inner), (DP, TP), dtype=dtype),
        "wk": ParamDef((d_inner, d_inner), (DP, TP), dtype=dtype),
        "wv": ParamDef((d_inner, d_inner), (DP, TP), dtype=dtype),
        "wif": ParamDef((d_inner, 2 * n_heads), (DP, None), dtype=dtype),
        "out_norm": ParamDef((d_inner,), (None,), "ones", dtype=dtype),
        "down_proj": ParamDef((d_inner, d_model), (TP, DP), dtype=dtype),
    }


def mlstm_block(params, x, cfg, state=None, *, chunk: int = 128):
    """xLSTM mLSTM block (matrix memory, exp input gating + normalizer)."""
    B, S, d = x.shape
    H = cfg.n_heads
    d_inner = 2 * d
    dh = d_inner // H

    h = rms_norm(x, params["norm"])
    up = dense(h, params["up_proj"])
    xm, z = jnp.split(up, 2, axis=-1)

    q = dense(xm, params["wq"]).reshape(B, S, H, dh) / math.sqrt(dh)
    k = dense(xm, params["wk"]).reshape(B, S, H, dh) / math.sqrt(dh)
    v = dense(xm, params["wv"]).reshape(B, S, H, dh)
    gates = dense(xm, params["wif"]).astype(jnp.float32)
    i_gate = jnp.exp(jnp.minimum(gates[..., :H], 4.0))       # capped exp
    log_f = jax.nn.log_sigmoid(gates[..., H:])               # [B,S,H]

    ki = k * i_gate[..., None].astype(k.dtype)
    s0 = None if state is None else state[0]
    n0 = None if state is None else state[1]
    y, s_final = chunked_linear_rnn(q, ki, v, log_f, s0, chunk=chunk)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    nrm, n_final = chunked_linear_rnn(q, ki, ones, log_f, n0, chunk=chunk)
    y = y.astype(jnp.float32) / jnp.maximum(jnp.abs(nrm.astype(jnp.float32)), 1.0)

    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["out_norm"])
    return x + dense(y, params["down_proj"]), (s_final, n_final)


def slstm_defs(d_model: int, n_heads: int, dtype, *, pf: float = 4 / 3) -> dict:
    dh = d_model // n_heads
    # round the GeGLU hidden to a TP-friendly multiple (sharding divisibility)
    d_ff = -(-int(pf * d_model) // 64) * 64
    return {
        "norm": ParamDef((d_model,), (None,), "ones", dtype=dtype),
        "wx": ParamDef((d_model, 4 * d_model), (DP, None), dtype=dtype),
        "r": ParamDef((n_heads, dh, 4 * dh), (None, None, None), dtype=dtype,
                      scale=0.5),
        "ff_norm": ParamDef((d_model,), (None,), "ones", dtype=dtype),
        "ff_in": ParamDef((d_model, 2 * d_ff), (DP, TP), dtype=dtype),
        "ff_out": ParamDef((d_ff, d_model), (TP, DP), dtype=dtype),
    }


def slstm_block(params, x, cfg, state=None):
    """xLSTM sLSTM block: sequential scalar-memory recurrence (not
    parallelizable — the paper says so) + GeGLU feed-forward."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H

    h = rms_norm(x, params["norm"])
    wx = dense(h, params["wx"])                 # [B, S, 4d]

    if state is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        state = (zeros, zeros, jnp.zeros((B, H, dh), jnp.float32) - 10.0,
                 jnp.zeros((B, H, dh), jnp.float32))
    c0, n0, m0, h0 = state

    r = params["r"].astype(jnp.float32)

    def step(carry, wx_t):
        c, n, m, hprev = carry                          # [B, H, dh]
        rec = jnp.einsum("bhd,hdk->bhk", hprev, r)      # [B, H, 4dh]
        gx = wx_t.astype(jnp.float32).reshape(B, H, 4 * dh) + rec
        zt, it, ft, ot = jnp.split(gx, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c = f_p * c + i_p * zt
        n = f_p * n + i_p
        hnew = ot * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, hnew), hnew

    (c, n, m, hl), ys = lax.scan(step, (c0, n0, m0, h0),
                                 wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    x = x + y
    # GeGLU FF
    hf = rms_norm(x, params["ff_norm"])
    a, b = jnp.split(dense(hf, params["ff_in"]), 2, axis=-1)
    ff = jax.nn.gelu(a.astype(jnp.float32)).astype(x.dtype) * b
    return x + dense(ff, params["ff_out"]), (c, n, m, hl)
