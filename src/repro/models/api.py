"""Model API: one surface over all families for the launcher, dry-run,
trainer and serving engine.

  * ``model_defs(cfg)`` / ``init_params`` / ``param_pspecs``
  * ``loss(cfg)``                              — train/prefill forward+loss
  * ``batch_specs(cfg, shape)``                — input ShapeDtypeStructs + specs
  * ``decode_state_spec(cfg, shape, mesh_dp)`` — serve-state struct + specs
  * ``init_decode_state(cfg, shape, mesh_dp)`` — concrete serve state
  * ``decode_step(cfg)``                       — (params, state, tokens) ->
                                                 (state, logits)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig, ShapeConfig
from repro.core import expertplane, kvplane
from . import attention as attn_lib
from . import encdec as encdec_lib
from . import lm as lm_lib
from . import mlp as mlp_lib
from . import ssm as ssm_lib
from .common import DP, TP, dense, init_params as _init, pspecs as _pspecs, \
    rms_norm, shapes as _shapes
from .lm import pad_vocab

PAGE_TOKENS = 64          # KV page size (tokens) across the framework
SPARSE_TOPK = 64          # pages selected per sparse decode step (global)
SPARSE_LOCAL_FRAMES = 96  # frames per shard in sparse mode
FETCH_BUDGET = 4          # pages fetched per shard per step
KIMI_HOT_EXPERTS = 32     # resident experts per layer (kimi serve)


def model_defs(cfg: ArchConfig) -> dict:
    if cfg.family == "encdec":
        return encdec_lib.model_defs(cfg)
    return lm_lib.model_defs(cfg)


def init_params(cfg: ArchConfig, key):
    return _init(model_defs(cfg), key)


def param_shapes(cfg: ArchConfig):
    return _shapes(model_defs(cfg))


def param_pspecs(cfg: ArchConfig):
    return _pspecs(model_defs(cfg))


def opt_state_pspecs(cfg: ArchConfig, opt_name: str):
    """Optimizer-state logical specs mirroring the parameter specs."""
    ps = param_pspecs(cfg)
    if opt_name == "adamw":
        return {"mu": ps, "nu": ps}
    if opt_name == "adafactor":
        def per(spec):
            spec = tuple(spec)
            if len(spec) >= 2:
                return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]}
            return {"v": spec}
        return {"f": jax.tree.map(per, ps,
                                  is_leaf=lambda s: isinstance(s, tuple))}
    raise ValueError(opt_name)


def loss(cfg: ArchConfig) -> Callable:
    if cfg.family == "encdec":
        return functools.partial(encdec_lib.loss_fn, cfg)
    return functools.partial(lm_lib.loss_fn, cfg)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Returns {name: (ShapeDtypeStruct, logical_spec)} for the step input."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    out = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = (jax.ShapeDtypeStruct((B, S), i32), ("batch", None))
        if shape.kind == "train":
            out["labels"] = (jax.ShapeDtypeStruct((B, S), i32), ("batch", None))
        if cfg.family == "encdec":
            senc = max(S // 4, 128)
            out["frames"] = (jax.ShapeDtypeStruct((B, senc, cfg.d_model),
                                                  cfg.dtype), ("batch", None, None))
        if cfg.frontend == "vision":
            out["patches"] = (jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq, cfg.frontend_dim), cfg.dtype),
                ("batch", None, None))
    else:  # decode / decode_long: one new token per sequence
        # batch=1 (long-context) cannot shard over dp -> replicate
        tok_spec = (DP,) if B > 1 else (None,)
        out["tokens"] = (jax.ShapeDtypeStruct((B,), i32), tok_spec)
    return out


# --------------------------------------------------------------------------
# serve state construction
# --------------------------------------------------------------------------

def _kv_cfg_dense(cfg: ArchConfig, B: int, S: int) -> kvplane.KVPlaneConfig:
    NP = -(-S // PAGE_TOKENS)
    return kvplane.KVPlaneConfig(
        kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, page_tokens=PAGE_TOKENS,
        num_pages=NP, num_frames=B * NP, batch=B, dtype=cfg.dtype)


def _kv_cfg_window(cfg: ArchConfig, B: int) -> kvplane.KVPlaneConfig:
    NP = -(-cfg.sliding_window // PAGE_TOKENS)
    return kvplane.KVPlaneConfig(
        kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, page_tokens=PAGE_TOKENS,
        num_pages=NP, num_frames=B * NP, batch=B, dtype=cfg.dtype)


def _kv_cfg_sparse(cfg: ArchConfig, S: int, shards: int
                   ) -> kvplane.KVPlaneConfig:
    NP = -(-S // (PAGE_TOKENS * shards))
    frames = min(SPARSE_LOCAL_FRAMES, NP)
    return kvplane.KVPlaneConfig(
        kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, page_tokens=PAGE_TOKENS,
        num_pages=NP, num_frames=frames, batch=1,
        sparse_topk=min(max(SPARSE_TOPK // shards, 4), frames),
        fetch_budget=min(FETCH_BUDGET, frames), dtype=cfg.dtype)


class ServeState(NamedTuple):
    """Generic serve-state container: family-specific pytrees inside."""
    lengths: jnp.ndarray          # [B] tokens already in context
    kv: Any                       # stacked plane states / recurrent states
    extra: Any                    # family-specific (cross KV, expert planes…)


def _n_groups(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        return cfg.n_layers // 2
    if cfg.family == "hybrid":
        return 6
    if cfg.family == "encdec":
        return cfg.dec_layers
    return cfg.n_layers


def _stack(n, make_one):
    return jax.vmap(lambda _: make_one())(jnp.arange(n))


def init_decode_state(cfg: ArchConfig, shape: ShapeConfig, shards: int = 1,
                      enc_len: int = 0) -> ServeState:
    """Concrete zero-initialized serve state (used at small scale and as the
    eval_shape template for the dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    L = _n_groups(cfg)
    long = shape.kind == "decode_long"
    fam = cfg.family
    lengths = jnp.zeros((B,), jnp.int32)
    extra = ()

    if fam == "ssm":   # xLSTM: recurrent states, O(1) in S
        d_inner = 2 * cfg.d_model
        dh_m = d_inner // cfg.n_heads
        dh_s = cfg.d_model // cfg.n_heads
        def one():
            return {
                "mlstm_s": jnp.zeros((B, cfg.n_heads, dh_m, dh_m), jnp.float32),
                "mlstm_n": jnp.zeros((B, cfg.n_heads, dh_m, 1), jnp.float32),
                "slstm": (jnp.zeros((B, cfg.n_heads, dh_s), jnp.float32),) * 2
                + (jnp.zeros((B, cfg.n_heads, dh_s), jnp.float32) - 10.0,
                   jnp.zeros((B, cfg.n_heads, dh_s), jnp.float32)),
            }
        return ServeState(lengths, _stack(L, one), extra)

    if fam == "hybrid":   # zamba2: per-group 5 mamba states + shared-attn KV
        d_inner = 2 * cfg.d_model
        H = d_inner // 64
        N = cfg.ssm_state
        if long:
            kvc = _kv_cfg_sparse(cfg, S, shards)
            make_kv = lambda: _stack(shards, lambda: kvplane.init(kvc))
        else:
            kvc = _kv_cfg_dense(cfg, B, S)
            make_kv = lambda: kvplane.init(kvc)
        def one():
            return {
                "conv": jnp.zeros((5, B, 3, d_inner + 2 * N), cfg.dtype),
                "ssm": jnp.zeros((5, B, H, N, 64), jnp.float32),
                "attn_kv": make_kv(),
            }
        tail = {"conv": jnp.zeros((2, B, 3, d_inner + 2 * N), cfg.dtype),
                "ssm": jnp.zeros((2, B, H, N, 64), jnp.float32)}
        return ServeState(lengths, _stack(L, one), tail)

    if fam == "encdec":
        senc = enc_len or max(S // 4, 128)
        kvc = _kv_cfg_dense(cfg, B, S)
        kv = _stack(L, lambda: kvplane.init(kvc))
        cross = {
            "k": jnp.zeros((L, B, senc, cfg.n_kv_heads, cfg.hd), cfg.dtype),
            "v": jnp.zeros((L, B, senc, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        }
        return ServeState(lengths, kv, cross)

    # decoder-only attention families (dense / moe / vlm)
    if long:
        if cfg.sliding_window:
            kvc = _kv_cfg_window(cfg, B)
            kv = _stack(L, lambda: kvplane.init(kvc))
        else:
            kvc = _kv_cfg_sparse(cfg, S, shards)
            kv = _stack(L, lambda: _stack(shards, lambda: kvplane.init(kvc)))
    else:
        kvc = _kv_cfg_dense(cfg, B, S)
        kv = _stack(L, lambda: kvplane.init(kvc))

    if cfg.atlas_experts and cfg.moe_experts:
        epc = _expert_cfg(cfg)
        extra = _stack(L, lambda: expertplane.init(epc))
    return ServeState(lengths, kv, extra)


def _expert_cfg(cfg: ArchConfig) -> expertplane.ExpertPlaneConfig:
    return expertplane.ExpertPlaneConfig(
        n_experts=cfg.moe_experts, d_model=cfg.d_model, d_ff=cfg.d_ff,
        hot_slots=min(KIMI_HOT_EXPERTS, cfg.moe_experts), topk=cfg.moe_topk,
        fetch_budget=cfg.moe_topk, dtype=cfg.dtype)


# --------------------------------------------------------------------------
# decode step
# --------------------------------------------------------------------------

def _embed_tokens(cfg, params, tokens):
    vp = pad_vocab(cfg.vocab)
    one_hot = jax.nn.one_hot(tokens, vp, dtype=params["embed"].dtype)
    x = jnp.einsum("bv,vd->bd", one_hot, params["embed"])
    return (x * math.sqrt(cfg.d_model))[:, None, :]     # [B, 1, d]


def _logits(cfg, params, x):
    x = rms_norm(x, params["final_ln"])
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"]
                          ).astype(jnp.float32)[:, 0]
    return dense(x, params["lm_head"]).astype(jnp.float32)[:, 0]


def _attn_qkv(gp, x, lengths, cfg):
    """Project one decode token; returns q [B,H,Dh], k/v [B,KVH,Dh]
    (RoPE applied at absolute positions)."""
    B = x.shape[0]
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    from .common import rope
    q = dense(x, gp["wq"]).reshape(B, 1, H, Dh)
    k = dense(x, gp["wk"]).reshape(B, 1, KVH, Dh)
    v = dense(x, gp["wv"]).reshape(B, 1, KVH, Dh)
    q = rope(q, lengths[:, None], cfg.rope_theta)
    k = rope(k, lengths[:, None], cfg.rope_theta)
    return q[:, 0], k[:, 0], v[:, 0]


def _plane_attend(cfg, kvc, gp, x2d, kv, lengths, mode):
    """One attention application through the KV plane.  x2d: [B, 1, d]."""
    q, k, v = _attn_qkv(gp, x2d, lengths, cfg)
    if mode == "dense":
        kv = kvplane.append_dense(kvc, kv, k, v, lengths)
        out, kv = kvplane.attend_dense(kvc, kv, q, lengths + 1)
    elif mode == "window":
        kv = kvplane.append_window(kvc, kv, k, v, lengths)
        out, kv = kvplane.attend_window(kvc, kv, q, lengths + 1)
    else:  # sparse (sharded)
        kv = kvplane.append_sharded(kvc, kv, k, v, lengths)
        out, kv = kvplane.sharded_sparse_decode(kvc, kv, q, lengths + 1)
    B = x2d.shape[0]
    out = dense(out.reshape(B, 1, cfg.n_heads * cfg.hd), gp["wo"])
    return out, kv


def decode_step(cfg: ArchConfig, shape: ShapeConfig, shards: int = 1):
    """Build the jittable serve step: (params, state, tokens) ->
    (state, logits [B, vocab_padded])."""
    long = shape.kind == "decode_long"
    fam = cfg.family
    B, S = shape.global_batch, shape.seq_len

    if fam in ("dense", "moe", "vlm"):
        if long and cfg.sliding_window:
            kvc, mode = _kv_cfg_window(cfg, B), "window"
        elif long:
            kvc, mode = _kv_cfg_sparse(cfg, S, shards), "sparse"
        else:
            kvc, mode = _kv_cfg_dense(cfg, B, S), "dense"
        epc = _expert_cfg(cfg) if (cfg.atlas_experts and cfg.moe_experts) else None

        def step(params, state: ServeState, tokens):
            x = _embed_tokens(cfg, params, tokens)
            lengths = state.lengths

            def body(carry, xs):
                x = carry
                if epc is not None:
                    gp, kv, ep = xs
                else:
                    gp, kv = xs
                h = rms_norm(x, gp["ln1"])
                o, kv = _plane_attend(cfg, kvc, gp["attn"], h, kv, lengths,
                                      mode)
                x = x + o
                h = rms_norm(x, gp["ln2"])
                if cfg.moe_experts and epc is not None:
                    o2d, ep = expertplane.moe_decode(
                        epc, ep, gp["moe"]["router"], h[:, 0],
                        gp["moe"]["wi"], gp["moe"]["wg"], gp["moe"]["wo"])
                    x = x + o2d[:, None, :]
                    return x, (kv, ep)
                elif cfg.moe_experts:
                    o, _aux = mlp_lib.moe(gp["moe"], h,
                                          n_experts=cfg.moe_experts,
                                          topk=cfg.moe_topk)
                    x = x + o
                else:
                    x = x + mlp_lib.mlp(gp["mlp"], h)
                return x, (kv,)

            xs = ((params["blocks"], state.kv, state.extra) if epc is not None
                  else (params["blocks"], state.kv))
            x, new = lax.scan(body, x, xs)
            kv_new = new[0]
            extra_new = new[1] if epc is not None else state.extra
            logits = _logits(cfg, params, x)
            return ServeState(lengths + 1, kv_new, extra_new), logits

        return step

    if fam == "ssm":   # xLSTM
        def step(params, state: ServeState, tokens):
            x = _embed_tokens(cfg, params, tokens)
            lengths = state.lengths

            def body(carry, xs):
                x = carry
                gp, st = xs
                x, (s_m, n_m) = ssm_lib.mlstm_block(
                    gp["mlstm"], x, cfg, (st["mlstm_s"], st["mlstm_n"]),
                    chunk=1)
                x, s_s = ssm_lib.slstm_block(gp["slstm"], x, cfg, st["slstm"])
                return x, {"mlstm_s": s_m, "mlstm_n": n_m, "slstm": s_s}

            x, kv_new = lax.scan(body, x, (params["blocks"], state.kv))
            return (ServeState(lengths + 1, kv_new, state.extra),
                    _logits(cfg, params, x))

        return step

    if fam == "hybrid":   # zamba2
        if long:
            kvc, mode = _kv_cfg_sparse(cfg, S, shards), "sparse"
        else:
            kvc, mode = _kv_cfg_dense(cfg, B, S), "dense"

        def step(params, state: ServeState, tokens):
            x = _embed_tokens(cfg, params, tokens)
            lengths = state.lengths
            sp = params["shared_attn"]

            def one_mamba(x, p, conv, ssm_s):
                y, (nc, ns) = ssm_lib.mamba2_block(p, x, cfg, (conv, ssm_s),
                                                   chunk=1)
                return y, nc, ns

            def body(carry, xs):
                x = carry
                gp, st = xs

                def mamba_scan(x, inner):
                    p, conv, ssm_s = inner
                    y, nc, ns = one_mamba(x, p, conv, ssm_s)
                    return y, (nc, ns)

                x, (nconv, nssm) = lax.scan(
                    mamba_scan, x, (gp["mamba"], st["conv"], st["ssm"]))
                h = rms_norm(x, sp["ln1"])
                o, kv = _plane_attend(cfg, kvc, sp["attn"], h, st["attn_kv"],
                                      lengths, mode)
                x = x + o
                h = rms_norm(x, sp["ln2"])
                x = x + mlp_lib.mlp(sp["mlp"], h)
                return x, {"conv": nconv, "ssm": nssm, "attn_kv": kv}

            x, kv_new = lax.scan(body, x, (params["blocks"], state.kv))

            def tail_scan(x, inner):
                p, conv, ssm_s = inner
                y, nc, ns = one_mamba(x, p, conv, ssm_s)
                return y, (nc, ns)

            x, (tconv, tssm) = lax.scan(
                tail_scan, x, (params["tail"], state.extra["conv"],
                               state.extra["ssm"]))
            return (ServeState(lengths + 1, kv_new,
                               {"conv": tconv, "ssm": tssm}),
                    _logits(cfg, params, x))

        return step

    if fam == "encdec":
        kvc = _kv_cfg_dense(cfg, B, S)

        def step(params, state: ServeState, tokens):
            x = _embed_tokens(cfg, params, tokens)
            lengths = state.lengths
            cross = state.extra

            def body(carry, xs):
                x = carry
                gp, kv, ck, cv = xs
                h = rms_norm(x, gp["ln1"])
                o, kv = _plane_attend(cfg, kvc, gp["self_attn"], h, kv,
                                      lengths, "dense")
                x = x + o
                # cross attention against the (static) encoder memory
                h = rms_norm(x, gp["lnx"])
                q = dense(h, gp["cross_attn"]["wq"]).reshape(
                    B, 1, cfg.n_heads, cfg.hd)
                o = attn_lib.full_attention(q, ck, cv, causal=False)
                o = dense(o.reshape(B, 1, cfg.n_heads * cfg.hd),
                          gp["cross_attn"]["wo"])
                x = x + o
                h = rms_norm(x, gp["ln2"])
                x = x + mlp_lib.mlp(gp["mlp"], h)
                return x, kv

            x, kv_new = lax.scan(
                body, x, (params["dec_blocks"], state.kv,
                          cross["k"], cross["v"]))
            return (ServeState(lengths + 1, kv_new, cross),
                    _logits(cfg, params, x))

        return step

    raise ValueError(fam)


# --------------------------------------------------------------------------
# serve-state logical partition specs (mirrors init_decode_state)
# --------------------------------------------------------------------------

def _kv_state_pspecs(shard_batch: bool, layer_axes: int = 1,
                     sparse_sharded: bool = False):
    """Spec tree for a (stacked) KVPlaneState.  ``layer_axes`` leading None
    axes are prepended (layer stacking); sparse mode adds a shard axis that
    carries the dp sharding instead of the batch."""
    lead = (None,) * layer_axes
    if sparse_sharded:
        lead = lead + (DP,)            # [L, D(shards), ...]
        b = None
    else:
        b = DP if shard_batch else None
    f = b if not sparse_sharded else None   # frames are batch-major in dense
    # dense mode keeps a size-1 slab placeholder -> replicated
    sl = None if not sparse_sharded else None
    return kvplane.KVPlaneState(
        k_frames=lead + (None, f, None, None),
        v_frames=lead + (None, f, None, None),
        page_table=lead + (b, None),
        k_slab=lead + (None, sl, None, None),
        v_slab=lead + (None, sl, None, None),
        kmax=lead + (None, sl, None),
        kmin=lead + (None, sl, None),
        cat=lead + (b, None, None),
        psf=lead + (b, None),
        hot_hint=lead + (b, None, None),
        page_rows=lead + (b, None),
        frame_page=lead + (f,),
        clock=lead + (f,),
        step=lead,
    )


def _expert_state_pspecs():
    lead = (None,)   # layer axis
    return expertplane.ExpertPlaneState(
        hot_wi=lead + (None, DP, None),
        hot_wg=lead + (None, DP, None),
        hot_wo=lead + (None, None, DP),
        slot_of=lead + (None,),
        expert_of=lead + (None,),
        clock=lead + (None,),
        access=lead + (None,),
        step=lead,
    )


def serve_state_pspecs(cfg: ArchConfig, shape: ShapeConfig, shards: int = 1):
    long = shape.kind == "decode_long"
    B = shape.global_batch
    shard_b = B > 1
    fam = cfg.family
    lengths = (DP,) if shard_b else (None,)
    extra = ()

    if fam == "ssm":
        b = DP if shard_b else None
        kv = {"mlstm_s": (None, b, None, None, None),
              "mlstm_n": (None, b, None, None, None),
              "slstm": ((None, b, None, None),) * 4}
        return ServeState(lengths, kv, extra)

    if fam == "hybrid":
        b = DP if shard_b else None
        if long:
            akv = _kv_state_pspecs(False, layer_axes=1, sparse_sharded=True)
        else:
            akv = _kv_state_pspecs(shard_b, layer_axes=1)
        kv = {"conv": (None, None, b, None, None),
              "ssm": (None, None, b, None, None, None),
              "attn_kv": akv}
        tail = {"conv": (None, b, None, None),
                "ssm": (None, b, None, None, None)}
        return ServeState(lengths, kv, tail)

    if fam == "encdec":
        kv = _kv_state_pspecs(shard_b, layer_axes=1)
        cross = {"k": (None, DP if shard_b else None, None, None, None),
                 "v": (None, DP if shard_b else None, None, None, None)}
        return ServeState(lengths, kv, cross)

    if long and not cfg.sliding_window:
        kv = _kv_state_pspecs(False, layer_axes=1, sparse_sharded=True)
    else:
        kv = _kv_state_pspecs(shard_b, layer_axes=1)
    if cfg.atlas_experts and cfg.moe_experts:
        extra = _expert_state_pspecs()
    return ServeState(lengths, kv, extra)


# --------------------------------------------------------------------------
# step builders (train / prefill)
# --------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt):
    lf = loss(cfg)

    def train_step(params, opt_state, step, batch):
        lv, grads = jax.value_and_grad(lf)(params, batch)
        new_params, new_opt, gnorm = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, step + 1, lv, gnorm

    return train_step


def make_prefill_step(cfg: ArchConfig):
    """Prefill: full forward, emit last-token logits (continuation input)."""
    if cfg.family == "encdec":
        def step(params, batch):
            enc_out = encdec_lib.encode(cfg, params, batch["frames"])
            logits = encdec_lib.decode_train(cfg, params, batch["tokens"],
                                             enc_out)
            return logits[:, -1]
        return step

    def step(params, batch):
        logits, _ = lm_lib.forward(cfg, params, batch["tokens"],
                                   batch.get("patches"))
        return logits[:, -1]
    return step
