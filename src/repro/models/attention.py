"""Attention: GQA/MQA with chunked (flash-style) causal training attention,
sliding-window support, cross-attention, and cache-based decode."""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .common import DP, TP, ParamDef, dense, rope

NEG_INF = -1e30


def attn_defs(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype) -> dict:
    return {
        "wq": ParamDef((d_model, n_heads * head_dim), (DP, TP), dtype=dtype),
        "wk": ParamDef((d_model, n_kv_heads * head_dim), (DP, TP), dtype=dtype),
        "wv": ParamDef((d_model, n_kv_heads * head_dim), (DP, TP), dtype=dtype),
        "wo": ParamDef((n_heads * head_dim, d_model), (TP, DP), dtype=dtype),
    }


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[B, S, KVH, Dh] -> [B, S, KVH*G, Dh] by head-group repetition."""
    if groups == 1:
        return k
    b, s, kvh, dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, groups, dh))
    return k.reshape(b, s, kvh * groups, dh)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      q_offset: int = 0, chunk_q: int = 512,
                      chunk_k: int = 512) -> jnp.ndarray:
    """Flash-style attention with online softmax over KV chunks.

    q: [B, Sq, H, Dh]; k/v: [B, Sk, KVH, Dh]  (H = KVH * G)
    ``window > 0`` restricts attention to the last ``window`` positions
    (sliding-window attention).  ``q_offset`` is the absolute position of
    q[0] relative to k[0] (for prefill continuation / cross-chunk decode).
    Returns [B, Sq, H, Dh].
    """
    B, Sq, H, Dh = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    k = _repeat_kv(k, G)
    v = _repeat_kv(v, G)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))

    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    while Sq % cq:
        cq //= 2
    while Sk % ck:
        ck //= 2
    nq, nk = Sq // cq, Sk // ck

    q = q.reshape(B, nq, cq, H, Dh)

    def q_chunk(qi, qc):
        # qc: [B, cq, H, Dh]
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def kv_chunk(ki, carry):
            m, l, acc = carry
            kc = lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=1)
            vc = lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=1)
            k_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32),
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = alpha * acc + jnp.einsum("bhqk,bkhd->bhqd", p,
                                           vc.astype(jnp.float32))
            return m_new, l, acc

        m0 = jnp.full((B, H, cq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq, 1), jnp.float32)
        a0 = jnp.zeros((B, H, cq, Dh), jnp.float32)

        # causal + window skipping: only scan kv chunks that can be visible
        m, l, acc = lax.fori_loop(0, nk, kv_chunk, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)
        return out.transpose(0, 2, 1, 3).astype(v.dtype)  # [B, cq, H, Dh]

    out = lax.map(lambda args: q_chunk(*args),
                  (jnp.arange(nq), q.transpose(1, 0, 2, 3, 4)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


def full_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Reference unchunked attention (small shapes / tests)."""
    B, Sq, H, Dh = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    k = _repeat_kv(k, G)
    v = _repeat_kv(v, G)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(Dh))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attend(params, x, positions, cfg, *, kv_override=None, causal=True,
           window=0, q_offset=0, chunked=True):
    """Standard attention block body (pre-norm handled by caller).

    Returns (out [B, S, d_model], (k, v) as produced)."""
    B, S, _ = x.shape
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = dense(x, params["wq"]).reshape(B, S, H, Dh)
    if kv_override is None:
        k = dense(x, params["wk"]).reshape(B, S, KVH, Dh)
        v = dense(x, params["wv"]).reshape(B, S, KVH, Dh)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    q = rope(q, positions, cfg.rope_theta)
    fn = chunked_attention if chunked else full_attention
    out = fn(q, k, v, causal=causal, window=window, q_offset=q_offset)
    out = dense(out.reshape(B, S, H * Dh), params["wo"])
    return out, (k, v)


def decode_attend(params, x, position, cache_k, cache_v, cfg, *, window=0):
    """Single-token decode against a dense in-HBM cache.

    x: [B, 1, d]; cache_k/v: [B, Smax, KVH, Dh]; position: [B] int32 (next
    index to write).  Returns (out [B, 1, d], new_cache_k, new_cache_v)."""
    B = x.shape[0]
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    Smax = cache_k.shape[1]
    q = dense(x, params["wq"]).reshape(B, 1, H, Dh)
    k = dense(x, params["wk"]).reshape(B, 1, KVH, Dh)
    v = dense(x, params["wv"]).reshape(B, 1, KVH, Dh)
    q = rope(q, position[:, None], cfg.rope_theta)
    k = rope(k, position[:, None], cfg.rope_theta)

    # scatter the new kv at each sequence's position
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, position].set(k[:, 0])
    cache_v = cache_v.at[bidx, position].set(v[:, 0])

    G = H // KVH
    kk = _repeat_kv(cache_k, G)
    vv = _repeat_kv(cache_v, G)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / jnp.sqrt(jnp.float32(Dh))
    pos = jnp.arange(Smax)
    mask = pos[None, :] <= position[:, None]
    if window > 0:
        mask &= pos[None, :] > (position[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vv.astype(jnp.float32)).astype(x.dtype)
    out = dense(out.reshape(B, 1, H * Dh), params["wo"])
    return out, cache_k, cache_v
