"""Unified decoder-only LM covering the dense / MoE / xLSTM / hybrid
families, with scan-over-layers + remat, GSPMD-ready logical shardings,
train / prefill / decode step bodies.

Layer stacking: layers are grouped into a repeating *pattern group* (e.g.
xLSTM: (mlstm, slstm); zamba2: 5x mamba2 + one shared-attention
application).  Parameters for scanned groups carry a leading group axis;
shared blocks (zamba2's attention) live outside the scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig
from . import attention as attn
from . import mlp as mlp_lib
from . import ssm
from .common import BATCH, DP, TP, ParamDef, dense, init_params, pspecs, \
    rms_norm, shard, stack_layers


def pad_vocab(vocab: int, multiple: int = 128) -> int:
    return -(-vocab // multiple) * multiple


# --------------------------------------------------------------------------
# block definitions per family
# --------------------------------------------------------------------------

def _attn_mlp_defs(cfg: ArchConfig):
    d = {
        "ln1": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "attn": attn.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.dtype),
        "ln2": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
    }
    if cfg.moe_experts:
        shard_ep = cfg.moe_experts % 16 == 0
        d["moe"] = mlp_lib.moe_defs(cfg.d_model, cfg.d_ff, cfg.moe_experts,
                                    shard_ep, cfg.dtype)
    else:
        d["mlp"] = mlp_lib.mlp_defs(cfg.d_model, cfg.d_ff, cfg.dtype)
    return d


def group_defs(cfg: ArchConfig) -> tuple[dict, int, dict]:
    """Returns (scanned_group_defs, n_groups, shared_defs)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _attn_mlp_defs(cfg), cfg.n_layers, {}
    if fam == "ssm":          # xLSTM: alternating mLSTM / sLSTM
        g = {
            "mlstm": ssm.mlstm_defs(cfg.d_model, cfg.n_heads, cfg.dtype),
            "slstm": ssm.slstm_defs(cfg.d_model, cfg.n_heads, cfg.dtype),
        }
        return g, cfg.n_layers // 2, {}
    if fam == "hybrid":       # zamba2: 6 groups of (5 mamba2 + shared attn)
        per_group = 5
        n_groups = 6
        g = {"mamba": stack_layers(
            ssm.mamba2_defs(cfg.d_model, cfg.ssm_state, cfg.dtype), per_group)}
        shared = {"shared_attn": _attn_mlp_defs(
            dataclasses.replace(cfg, moe_experts=0)),
            "tail": stack_layers(
                ssm.mamba2_defs(cfg.d_model, cfg.ssm_state, cfg.dtype), 2)}
        return g, n_groups, shared
    raise ValueError(fam)


def model_defs(cfg: ArchConfig) -> dict:
    vp = pad_vocab(cfg.vocab)
    g, n_groups, shared = group_defs(cfg)
    defs = {
        "embed": ParamDef((vp, cfg.d_model), (TP, DP), "embed", 0.02,
                          cfg.dtype),
        "blocks": stack_layers(g, n_groups),
        "final_ln": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        **shared,
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, vp), (DP, TP), dtype=cfg.dtype)
    if cfg.frontend == "vision":
        defs["patch_proj"] = ParamDef((cfg.frontend_dim, cfg.d_model),
                                      (None, DP), dtype=cfg.dtype)
    return defs


# --------------------------------------------------------------------------
# forward pass (train / prefill)
# --------------------------------------------------------------------------

class Aux(NamedTuple):
    moe_loss: jnp.ndarray


def _group_fwd(cfg: ArchConfig, shared_params, gi, gparams, x, positions):
    """One scanned group; returns new x and aux."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam in ("dense", "moe", "vlm"):
        h = rms_norm(x, gparams["ln1"])
        o, _ = attn.attend(gparams["attn"], h, positions, cfg,
                           window=cfg.sliding_window)
        x = x + o
        h = rms_norm(x, gparams["ln2"])
        if cfg.moe_experts:
            o, aux = mlp_lib.moe(gparams["moe"], h, n_experts=cfg.moe_experts,
                                 topk=cfg.moe_topk,
                                 capacity_factor=cfg.moe_capacity)
        else:
            o = mlp_lib.mlp(gparams["mlp"], h)
        x = x + o
    elif fam == "ssm":
        x, _ = ssm.mlstm_block(gparams["mlstm"], x, cfg)
        x, _ = ssm.slstm_block(gparams["slstm"], x, cfg)
    elif fam == "hybrid":
        def one_mamba(x, p):
            y, _ = ssm.mamba2_block(p, x, cfg)
            return y, None
        x, _ = lax.scan(one_mamba, x, gparams["mamba"])
        sp = shared_params["shared_attn"]
        h = rms_norm(x, sp["ln1"])
        o, _ = attn.attend(sp["attn"], h, positions, cfg)
        x = x + o
        h = rms_norm(x, sp["ln2"])
        x = x + mlp_lib.mlp(sp["mlp"], h)
    else:
        raise ValueError(fam)
    return x, aux


def forward(cfg: ArchConfig, params, tokens: jnp.ndarray,
            patches: Optional[jnp.ndarray] = None):
    """tokens [B, S] -> (logits [B, S, vocab_padded], Aux).

    For the vision family, ``patches`` [B, Np, frontend_dim] are projected
    and prepended as a prefix (logits for the prefix are produced but the
    loss masks them out)."""
    B, S = tokens.shape
    vp = pad_vocab(cfg.vocab)
    embed = params["embed"]

    one_hot = jax.nn.one_hot(tokens, vp, dtype=embed.dtype)
    x = jnp.einsum("bsv,vd->bsd", one_hot, embed)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    # keep activations batch-sharded over dp (GSPMD otherwise replicates
    # the batch through the layer scan -> 16x collective blowup; §Perf it.1)
    x = shard(x, (BATCH, None, None))

    if cfg.frontend == "vision" and patches is not None:
        pre = dense(patches.astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([pre, x], axis=1)
    St = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(St), (B, St))

    def body(carry, gparams):
        x, aux, gi = carry
        fwd = lambda x: _group_fwd(cfg, params, gi, gparams, x, positions)
        if cfg.remat:
            fwd = jax.checkpoint(
                fwd, policy=jax.checkpoint_policies.nothing_saveable)
        x, a = fwd(x)
        x = shard(x, (BATCH, None, None))
        return (x, aux + a, gi + 1), None

    (x, aux, _), _ = lax.scan(
        body, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        params["blocks"])

    if cfg.family == "hybrid":   # zamba2 tail layers
        def one_mamba(x, p):
            y, _ = ssm.mamba2_block(p, x, cfg)
            return y, None
        x, _ = lax.scan(one_mamba, x, params["tail"])

    x = rms_norm(x, params["final_ln"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, embed)
    else:
        logits = dense(x, params["lm_head"])
    logits = shard(logits, (BATCH, None, TP))
    if cfg.frontend == "vision" and patches is not None:
        logits = logits[:, -S:]
    return logits.astype(jnp.float32), Aux(aux)


def loss_fn(cfg: ArchConfig, params, batch) -> jnp.ndarray:
    """Next-token cross entropy (+ MoE aux)."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens, batch.get("patches"))
    vp = pad_vocab(cfg.vocab)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    one_hot = jax.nn.one_hot(labels, vp, dtype=jnp.float32)
    picked = jnp.einsum("bsv,bsv->bs", logits, one_hot)
    nll = (lse - picked) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux.moe_loss
