"""Encoder-decoder LM (seamless-m4t family).

The audio frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_enc, d_model] which the encoder consumes
directly.  Decoder = causal self-attention + cross-attention + MLP.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig
from . import attention as attn
from . import mlp as mlp_lib
from .common import BATCH, DP, TP, ParamDef, dense, rms_norm, shard, stack_layers
from .lm import pad_vocab


def enc_block_defs(cfg: ArchConfig):
    return {
        "ln1": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "attn": attn.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.dtype),
        "ln2": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "mlp": mlp_lib.mlp_defs(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def dec_block_defs(cfg: ArchConfig):
    return {
        "ln1": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "self_attn": attn.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, cfg.dtype),
        "lnx": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "cross_attn": attn.attn_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.hd, cfg.dtype),
        "ln2": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "mlp": mlp_lib.mlp_defs(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def model_defs(cfg: ArchConfig) -> dict:
    vp = pad_vocab(cfg.vocab)
    return {
        "embed": ParamDef((vp, cfg.d_model), (TP, DP), "embed", 0.02, cfg.dtype),
        "enc_blocks": stack_layers(enc_block_defs(cfg), cfg.enc_layers),
        "enc_ln": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "dec_blocks": stack_layers(dec_block_defs(cfg), cfg.dec_layers),
        "final_ln": ParamDef((cfg.d_model,), (None,), "ones", dtype=cfg.dtype),
        "lm_head": ParamDef((cfg.d_model, vp), (DP, TP), dtype=cfg.dtype),
    }


def encode(cfg: ArchConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: stub frame embeddings [B, S_enc, d_model] -> encoder output."""
    B, S, _ = frames.shape
    x = shard(frames.astype(cfg.dtype), (BATCH, None, None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, bp):
        def fwd(x):
            h = rms_norm(x, bp["ln1"])
            o, _ = attn.attend(bp["attn"], h, positions, cfg, causal=False)
            x = x + o
            h = rms_norm(x, bp["ln2"])
            return x + mlp_lib.mlp(bp["mlp"], h)
        if cfg.remat:
            fwd = jax.checkpoint(fwd,
                                 policy=jax.checkpoint_policies.nothing_saveable)
        return shard(fwd(x), (BATCH, None, None)), None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_ln"])


def decode_train(cfg: ArchConfig, params, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced decoder pass -> logits [B, S, vocab_padded]."""
    B, S = tokens.shape
    vp = pad_vocab(cfg.vocab)
    one_hot = jax.nn.one_hot(tokens, vp, dtype=cfg.dtype)
    x = jnp.einsum("bsv,vd->bsd", one_hot, params["embed"])
    x = shard(x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype),
              (BATCH, None, None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                               (B, enc_out.shape[1]))

    def body(x, bp):
        def fwd(x):
            h = rms_norm(x, bp["ln1"])
            o, _ = attn.attend(bp["self_attn"], h, positions, cfg)
            x = x + o
            h = rms_norm(x, bp["lnx"])
            kvh = cfg.n_kv_heads
            k = dense(enc_out, bp["cross_attn"]["wk"]).reshape(
                B, -1, kvh, cfg.hd)
            v = dense(enc_out, bp["cross_attn"]["wv"]).reshape(
                B, -1, kvh, cfg.hd)
            # no RoPE on cross-attention (position-agnostic memory keys)
            o, _ = attn.attend(bp["cross_attn"], h, positions * 0, cfg,
                               kv_override=(k, v), causal=False)
            x = x + o
            h = rms_norm(x, bp["ln2"])
            return x + mlp_lib.mlp(bp["mlp"], h)
        if cfg.remat:
            fwd = jax.checkpoint(fwd,
                                 policy=jax.checkpoint_policies.nothing_saveable)
        return shard(fwd(x), (BATCH, None, None)), None

    x, _ = lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x, params["final_ln"])
    return shard(dense(x, params["lm_head"]), (BATCH, None, TP)
                 ).astype(jnp.float32)


def loss_fn(cfg: ArchConfig, params, batch) -> jnp.ndarray:
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], enc_out)
    labels = batch["labels"]
    vp = pad_vocab(cfg.vocab)
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.einsum("bsv,bsv->bs", logits,
                        jax.nn.one_hot(labels, vp, dtype=jnp.float32))
    return ((lse - picked) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
